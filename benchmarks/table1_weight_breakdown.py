"""Table 1: FFN vs attention weight breakdown + arena consolidation.

The paper's table shows MoE models put ~95% of params in FFN (the weights
pool wins big) while dense models sit at 66-77%.  We compute the same
breakdown analytically from our configs, and — since the weights arena
landed — the device-bytes consequence: a consolidated expert-slab arena
sized for the hot working set vs the per-model-static baseline that keeps
every colocated model's FFN permanently device-resident.
"""
from __future__ import annotations

from repro.configs import ARCH_NAMES, PAPER_COLOC_SET, get_config
from repro.core.weight_pool import (DEFAULT_SLAB_BYTES, slabs_for_config,
                                    static_ffn_bytes)


def run(csv=print) -> dict:
    out = {}
    for name in ARCH_NAMES:
        cfg = get_config(name)
        c = cfg.param_counts()
        ffn = c["ffn"]
        attn = c["attn"] + c["ssm"]
        total = c["total"]
        share = ffn / total if total else 0.0
        csv(f"table1,{name},total_B={total / 1e9:.1f},ffn_B={ffn / 1e9:.1f},"
            f"attn_B={attn / 1e9:.2f},ffn_share={share * 100:.1f}%")
        out[name] = share
    # paper's claim: MoE models are ~95% FFN, dense 60-85%
    assert out["qwen3-moe-235b-a22b"] > 0.90
    assert out["moonshot-v1-16b-a3b"] > 0.90
    assert 0.5 < out["qwen3-14b"] < 0.9

    # --- consolidated arena vs per-model-static device bytes --------------
    # per-model-static: every colocated model's FFN device-resident (the
    # monolithic failure mode, paper §1); consolidated: ONE slab arena
    # sized for the hot model (cold models live on the host and activate
    # on demand).  Slab-rounding is the arena's only overhead.
    arena = {}
    for name in PAPER_COLOC_SET:
        cfg = get_config(name)
        slabs = slabs_for_config(cfg, DEFAULT_SLAB_BYTES)
        arena[name] = {
            "arena_slabs": slabs,
            "arena_GiB": slabs * DEFAULT_SLAB_BYTES / 2 ** 30,
            "static_GiB": static_ffn_bytes(cfg) / 2 ** 30,
        }
        csv(f"table1,{name},arena_slabs={slabs},"
            f"arena_GiB={arena[name]['arena_GiB']:.2f},"
            f"static_GiB={arena[name]['static_GiB']:.2f}")
    static_all = sum(v["static_GiB"] for v in arena.values())
    hot_one = max(v["arena_GiB"] for v in arena.values())
    cold_static = static_all - max(v["static_GiB"] for v in arena.values())
    freed = static_all - hot_one
    csv(f"table1,coloc_set,per_model_static_GiB={static_all:.2f},"
        f"consolidated_arena_GiB={hot_one:.2f},freed_GiB={freed:.2f},"
        f"saving={static_all / hot_one:.2f}x")
    # slab rounding must stay cheap (<5% per model), and consolidation must
    # free essentially ALL of the cold models' device bytes — what's left
    # on device is one hot model's slab-rounded FFN, nothing per-cold-model
    for name, v in arena.items():
        assert v["arena_GiB"] < v["static_GiB"] * 1.05, name
    assert freed > 0.95 * cold_static

    # --- per-phase device FFN bytes: prefill == decode == the arena -------
    # Prefill runs through the SAME (arena, slot_table) protocol as decode
    # (streaming per-layer uploads), so there is no full-tree prefill
    # column any more: prompt-phase device FFN bytes are slot_budget-
    # bounded, identical to decode, instead of the sum of every colocated
    # model's resident FFN tree.  Witnessed against the RUNTIME, not by
    # construction: a smoke engine must hold NO per-model param tree and
    # exactly slot_budget * slab_bytes of device FFN.
    from repro.configs import get_smoke_config
    from repro.runtime.engine import CrossPoolEngine

    engine = CrossPoolEngine(
        {n: get_smoke_config(n) for n in PAPER_COLOC_SET},
        page_budget=512, page_bytes=4096, slab_bytes=4096,
        max_batch=2, max_ctx=64)
    assert all(r.params is None for r in engine.runners.values() if r.paged), \
        "a paged runner holds a full param tree — prefill is not arena-bound"
    assert engine.arena.device_bytes() == \
        engine.arena.slot_budget * engine.arena.slab_bytes
    phase = {
        "prefill_device_ffn_GiB": hot_one,
        "decode_device_ffn_GiB": hot_one,
        "eliminated_full_tree_prefill_GiB": static_all,
    }
    csv(f"table1,phases,prefill_device_ffn_GiB={hot_one:.2f},"
        f"decode_device_ffn_GiB={hot_one:.2f},"
        f"eliminated_full_tree_prefill_GiB={static_all:.2f}")
    assert phase["prefill_device_ffn_GiB"] < static_all
    out["arena"] = {**arena, "per_model_static_GiB": static_all,
                    "consolidated_arena_GiB": hot_one,
                    "freed_GiB": freed, **phase}
    return out


if __name__ == "__main__":
    run()
