"""Pooling/parallelism strategies: how (arch x shape x mesh) maps to shardings.

Three strategies:

* ``train``      — DP over (pod,data) + FSDP weight sharding over the same
                   axes + TP over ``model`` (attention heads / FFN hidden /
                   expert axis).  Used by ``train_4k`` cells.
* ``monolithic`` — the kvcached-style serving baseline (paper §2.2): TP over
                   ``model`` *within* a replica, weights replicated across
                   ``data`` replicas, DP attention for KV-head-limited
                   models.  KV + weights colocated per replica.
* ``crosspool``  — the paper: FFN/expert weights consolidated ONCE across
                   the whole mesh (weights pool); KV caches sequence-sharded
                   so a single request sees the aggregate KV capacity of the
                   pool (KV-cache pool); attention executes where KV lives;
                   the boundary exchanges hidden states only.

A Strategy emits (a) path-pattern sharding rules for params and caches,
(b) :class:`Hooks` carrying with_sharding_constraint closures + the
sequence-sharded attention overrides, and (c) input/output shardings.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.hooks import Hooks
from repro.sharding import seq_attention
from repro.sharding.spec import RuleSet, batch_axes, pool_axes, safe_spec

CONSTRAIN = jax.lax.with_sharding_constraint


def _c(mesh: Mesh, *spec):
    """Constraint closure that degrades per-dim on non-divisibility."""
    def apply(x):
        return CONSTRAIN(x, NamedSharding(mesh, safe_spec(mesh, x.shape, spec)))
    return apply


@dataclass(frozen=True)
class PerfOpts:
    """Hillclimb levers for the §Perf iteration loop."""

    seq_parallel: bool = False      # shard the residual stream's S over
    #                                 'model' (Megatron-SP): cuts saved-carry
    #                                 memory Lx and turns TP allgathers into
    #                                 narrower ones
    compress_grads: bool = False    # error-feedback int8 DP reduction
    microbatches: Optional[int] = None  # override TRAIN_MICROBATCHES
    kv_seq_override: Optional[Tuple[str, ...]] = None  # decode KV shard axes
    moe_a2a: bool = False           # explicit all-to-all expert dispatch
    #                                 (+ for train: experts sharded on 'data')
    kv_dtype: Optional[str] = None  # "f8" = fp8-e4m3 KV cache (2x memory)
    f8_dispatch: bool = False       # fp8 a2a dispatch transport (2x payload)


@dataclass(frozen=True)
class Strategy:
    name: str
    mesh: Mesh
    cfg: ModelConfig
    shape: ShapeConfig
    perf: PerfOpts = PerfOpts()

    # ------------------------------------------------------------------
    # axis helpers
    # ------------------------------------------------------------------
    @property
    def bax(self) -> Tuple[str, ...]:
        return tuple(batch_axes(self.mesh))

    @property
    def pool(self) -> Tuple[str, ...]:
        """Expert/FSDP placement axes.

        Training: FSDP spans (pod, data).  Serving: the paper deploys the
        disaggregated pools WITHIN one or two nodes (§7 Related) — the
        ``pod`` axis is a pure replica axis, each pod holding its own
        complete weights+KV pools; sharding experts across pods would put
        the per-layer dispatch on the slow cross-pod fabric.
        """
        if self.name == "train":
            return tuple(pool_axes(self.mesh))
        return ("data",)

    @property
    def tp_all(self) -> Tuple[str, ...]:
        """Pool-wide axis tuple (dense weights pool).

        Excludes ``pod`` for serving strategies (pod = replica axis)."""
        if self.name == "train":
            return tuple(self.mesh.axis_names)
        return tuple(a for a in self.mesh.axis_names if a != "pod")

    @property
    def model_size(self) -> int:
        return self.mesh.shape["model"]

    @property
    def batch_sharded(self) -> bool:
        from repro.sharding.spec import axis_size
        return self.shape.global_batch % axis_size(self.mesh, self.bax) == 0 \
            and self.shape.global_batch >= axis_size(self.mesh, self.bax)

    @property
    def kv_seq_axes(self) -> Tuple[str, ...]:
        """Axes the KV sequence dim shards over under crosspool.

        When the batch occupies the data axes, only ``model`` is available;
        a batch-1 long-context request pools KV over the ENTIRE mesh — the
        paper's headline capability.
        """
        if self.perf.kv_seq_override is not None:
            return self.perf.kv_seq_override
        if not self.batch_sharded:
            return self.tp_all
        return ("model",)

    @property
    def type_ii(self) -> bool:
        """KV-head-limited (paper §2.2): fewer KV heads than TP width."""
        if self.cfg.attention == "mla":
            return True
        if self.cfg.attn_free:
            return False
        return self.cfg.n_kv_heads < self.model_size

    # ------------------------------------------------------------------
    # parameter rules
    # ------------------------------------------------------------------
    def param_rules(self) -> RuleSet:
        FSDP = self.pool          # ZeRO-style weight sharding axes
        TP = "model"
        POOL = self.pool
        ALL = self.tp_all

        if self.name == "train":
            if self.perf.moe_a2a:
                # data-EP: experts live with the batch axis, a2a dispatch
                moe_rules = [
                    ("*moe/router", (None, None)),
                    ("*moe/w[gu]", ("data", None, TP)),
                    ("*moe/wd", ("data", TP, None)),
                ]
            else:
                moe_rules = [
                    ("*moe/router", (FSDP, None)),
                    ("*moe/w[gu]", (TP, FSDP, None)),  # [L,E,d,f]: E@model
                    ("*moe/wd", (TP, None, FSDP)),
                ]
            rules = [
                ("embed/tok", (TP, FSDP)),
                ("embed/head", (FSDP, TP)),
                ("*attn/wq", (FSDP, TP)),
                ("*attn/wk", (FSDP, TP)),
                ("*attn/wv", (FSDP, TP)),
                ("*attn/wo", (TP, FSDP)),
                ("*attn/wuq", (FSDP, TP)),
                ("*attn/wdq", (FSDP, TP)),
                ("*attn/wdkv", (FSDP, TP)),
                ("*attn/wuk", (FSDP, TP)),
                ("*attn/wuv", (FSDP, TP)),
                ("*mlp/w[gui]", (FSDP, TP)),
                ("*mlp/w[do]", (TP, FSDP)),
                *moe_rules,
                ("*moe/shared/w[gu]", (FSDP, TP)),
                ("*moe/shared/wd", (TP, FSDP)),
                ("*ssm/in_proj", (FSDP, TP)),
                ("*ssm/out_proj", (TP, FSDP)),
                ("*ssm/conv_w", (None, TP)),
            ]
        elif self.name == "monolithic":
            # kvcached-style: TP inside a replica, replicated over data
            rules = [
                ("embed/tok", (TP, None)),
                ("embed/head", (None, TP)),
                ("*attn/wq", (None, TP)),
                ("*attn/wk", (None, TP)),
                ("*attn/wv", (None, TP)),
                ("*attn/wo", (TP, None)),
                ("*attn/wuq", (None, TP)),
                ("*attn/wdq", (None, TP)),
                ("*attn/wdkv", (None, TP)),
                ("*attn/wuk", (None, TP)),
                ("*attn/wuv", (None, TP)),
                ("*mlp/w[gui]", (None, TP)),
                ("*mlp/w[do]", (TP, None)),
                ("*moe/router", (None, None)),
                ("*moe/w[gu]", (TP, None, None)),   # E over model, replicated@data
                ("*moe/wd", (TP, None, None)),
                ("*moe/shared/w[gu]", (None, TP)),
                ("*moe/shared/wd", (TP, None)),
                ("*ssm/in_proj", (None, TP)),
                ("*ssm/out_proj", (TP, None)),
                ("*ssm/conv_w", (None, TP)),
            ]
        elif self.name == "crosspool":
            # weights pool consolidates FFN/expert weights across the WHOLE
            # mesh; attention (KV pool) stays TP over model.
            rules = [
                ("embed/tok", (TP, None)),
                ("embed/head", (None, TP)),
                ("*attn/wq", (None, TP)),
                ("*attn/wk", (None, TP)),
                ("*attn/wv", (None, TP)),
                ("*attn/wo", (TP, None)),
                ("*attn/wuq", (None, TP)),
                ("*attn/wdq", (None, TP)),
                ("*attn/wdkv", (None, TP)),
                ("*attn/wuk", (None, TP)),
                ("*attn/wuv", (None, TP)),
                # consolidated MoE weights pool: E over (pod,data), f over model
                ("*moe/router", (None, None)),
                ("*moe/w[gu]", (POOL, None, TP)),
                ("*moe/wd", (POOL, TP, None)),
                ("*moe/shared/w[gu]", (None, ALL)),
                ("*moe/shared/wd", (ALL, None)),
                # dense FFN weights pool: hidden dim across the whole mesh
                ("*mlp/w[gui]", (None, ALL)),
                ("*mlp/w[do]", (ALL, None)),
                ("*ssm/in_proj", (None, ALL)),
                ("*ssm/out_proj", (ALL, None)),
                ("*ssm/conv_w", (None, ALL)),
            ]
        else:
            raise ValueError(f"unknown strategy {self.name}")
        return RuleSet(rules, default=())

    # ------------------------------------------------------------------
    # cache rules
    # ------------------------------------------------------------------
    def cache_rules(self) -> RuleSet:
        B = self.bax if self.batch_sharded else None
        TP = "model"
        if self.name == "crosspool":
            KT = self.kv_seq_axes
            rules = [
                ("k", (B, KT, None, None)),         # [L,B,T,KV,hd]
                ("v", (B, KT, None, None)),
                ("latent", (B, KT, None)),          # MLA [L,B,T,r]
                ("rope", (B, KT, None)),
                ("gk", (B, KT, None, None)),        # gemma3 global [G,B,T,KV,hd]
                ("gv", (B, KT, None, None)),
                ("lk", (B, None, None, None)),      # ring [G,P-1,B,W,KV,hd]
                ("lv", (B, None, None, None)),
                ("lpos", (B, None)),
                ("ck", (B, None, None, None)),      # whisper cross (static)
                ("cv", (B, None, None, None)),
                ("h", (B, TP, None, None)),         # SSM state [L,B,H,P,N]
                ("tail_h", (B, TP, None, None)),
                ("conv", (B, None, None)),
                ("tail_conv", (B, None, None)),
            ]
        else:
            # monolithic: Type II -> DP attention (batch over data x model),
            # Type I -> KV heads over model, batch over data.
            if self.type_ii:
                dpa = self._dpa_axes()
                rules = [
                    ("k", (dpa, None, None, None)),
                    ("v", (dpa, None, None, None)),
                    ("latent", (dpa, None, None)),
                    ("rope", (dpa, None, None)),
                    ("gk", (dpa, None, None, None)),
                    ("gv", (dpa, None, None, None)),
                    ("lk", (dpa, None, None, None)),
                    ("lv", (dpa, None, None, None)),
                    ("lpos", (dpa, None)),
                    ("ck", (dpa, None, None, None)),
                    ("cv", (dpa, None, None, None)),
                    ("h", (dpa, None, None, None)),
                    ("tail_h", (dpa, None, None, None)),
                    ("conv", (dpa, None, None)),
                    ("tail_conv", (dpa, None, None)),
                ]
            else:
                rules = [
                    ("k", (B, None, TP, None)),
                    ("v", (B, None, TP, None)),
                    ("latent", (B, None, None)),
                    ("rope", (B, None, None)),
                    ("gk", (B, None, TP, None)),
                    ("gv", (B, None, TP, None)),
                    ("lk", (B, None, TP, None)),
                    ("lv", (B, None, TP, None)),
                    ("lpos", (B, None)),
                    ("ck", (B, None, TP, None)),
                    ("cv", (B, None, TP, None)),
                    ("h", (B, TP, None, None)),
                    ("tail_h", (B, TP, None, None)),
                    ("conv", (B, None, None)),
                    ("tail_conv", (B, None, None)),
                ]
        # patterns match the LAST path component
        rules = [("*" + name, spec) for name, spec in rules]
        return RuleSet(rules, default=())

    def _dpa_axes(self) -> Optional[Tuple[str, ...]]:
        """DP-attention batch axes for the monolithic baseline."""
        from repro.sharding.spec import axis_size
        cand = self.bax + ("model",)
        if self.shape.global_batch % axis_size(self.mesh, cand) == 0:
            return cand
        return self.bax if self.batch_sharded else None

    # ------------------------------------------------------------------
    # hooks
    # ------------------------------------------------------------------
    def hooks(self) -> Hooks:
        m = self.mesh
        B = self.bax if self.batch_sharded else None
        TP = "model"
        moe_apply = None
        if (self.perf.moe_a2a and self.cfg.is_moe and self.batch_sharded
                and self.cfg.n_experts % m.shape["data"] == 0):
            from repro.models import moe as moe_mod
            moe_apply = moe_mod.make_moe_a2a(
                m, self.cfg, expert_axis="data", tp_axis="model",
                batch_axes=self.bax, f8_dispatch=self.perf.f8_dispatch)

        if self.name == "train":
            F = self.pool
            # sequence parallelism: the residual stream (and thus the
            # scan-saved carries) shard S over 'model' instead of being
            # replicated across the TP group
            SP = TP if self.perf.seq_parallel else None
            return Hooks(
                act=_c(m, B, SP, None),
                attn_q=_c(m, B, None, TP, None),
                kv=_c(m, B, None, TP, None),
                ffn_hidden=_c(m, B, None, TP),
                moe_inputs=_c(m, "data" if self.perf.moe_a2a else TP,
                              None, None),
                moe_hidden=_c(m, "data" if self.perf.moe_a2a else TP,
                              None, None),
                logits=_c(m, B, None, TP),
                moe_apply=moe_apply,
            )
        if self.name == "monolithic":
            dpa = self._dpa_axes() if self.type_ii else B
            # under DP attention the model axis carries batch — hidden dims
            # must not re-use it
            hid = None if (dpa and TP in dpa) else TP
            return Hooks(
                act=_c(m, dpa, None, None),
                kv=_c(m, dpa, None, None, None) if self.type_ii
                else _c(m, B, None, TP, None),
                ffn_hidden=_c(m, dpa, None, hid),
                moe_inputs=_c(m, TP, None, None),
                moe_hidden=_c(m, TP, None, None),
                logits=_c(m, dpa, None, hid),
            )
        # crosspool
        POOL = self.pool
        KT = self.kv_seq_axes
        if self.cfg.attention == "mla" and self.cfg.mla:
            scale = (self.cfg.mla.qk_nope_head_dim
                     + self.cfg.mla.qk_rope_head_dim) ** -0.5
        elif self.cfg.head_dim:
            scale = self.cfg.head_dim ** -0.5
        else:
            scale = 1.0                      # attn-free: never used
        decode_attn = None
        decode_attn_mla = None
        if self.shape.is_decode and self.type_ii:
            if self.cfg.attention == "mla":
                decode_attn_mla = seq_attention.make_seq_mla_decode_attn(
                    m, KT, B, scale)
            else:
                decode_attn = seq_attention.make_seq_decode_attn(
                    m, KT, B, scale)
        # The pool boundary: hidden states entering the weights pool are
        # REPLICATED across the pool axes (the A-to-F all-gather over 'data'
        # IS the paper's hidden-state transfer — O(batch*d_model) bytes,
        # independent of context length); the FFN output returns to the
        # batch-sharded attention layout via reduce-scatter (F-to-A).
        # with explicit a2a dispatch, tokens stay batch-sharded at the
        # boundary (each token travels once); otherwise the boundary
        # replicates hidden states into the weights pool
        b_in = (_c(m, B, None, None) if moe_apply is not None
                else _c(m, None, None, None))
        return Hooks(
            act=_c(m, B, None, None),
            kv=(_c(m, B, KT, None, None) if not self.cfg.attn_free
                else _c(m, B, TP, None, None)),
            boundary_in=b_in,
            boundary_out=_c(m, B, None, None),
            ffn_hidden=_c(m, None, None, self.tp_all),
            moe_inputs=_c(m, POOL, None, None),
            moe_hidden=_c(m, POOL, None, TP),
            logits=_c(m, B, None, TP),
            decode_attn=decode_attn,
            decode_attn_mla=decode_attn_mla,
            moe_apply=moe_apply,
        )

    # ------------------------------------------------------------------
    # input/output shardings
    # ------------------------------------------------------------------
    def input_sharding(self, ndim: int, kind: str = "tokens") -> NamedSharding:
        B = self.bax if self.batch_sharded else None
        spec = (B,) + (None,) * (ndim - 1)
        return NamedSharding(self.mesh, P(*spec))

    def scalar_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def params_shardings(self, tree):
        return self.param_rules().tree_shardings(self.mesh, tree)

    def cache_shardings(self, tree):
        return self.cache_rules().tree_shardings(self.mesh, tree)


def make_strategy(name: str, mesh: Mesh, cfg: ModelConfig,
                  shape: ShapeConfig,
                  perf: Optional[PerfOpts] = None) -> Strategy:
    if name == "auto":
        if shape.kind == "train":
            name = "train"
        elif shape.kind == "prefill":
            # paper §4: prefill runs on separate temporal-multiplexing
            # engines (Aegaeon-style), NOT through the disaggregated pools —
            # the hidden-state boundary cost scales with batch*tokens and
            # only decode's tiny token counts amortize it.
            name = "monolithic"
        else:
            name = "crosspool"
    return Strategy(name, mesh, cfg, shape, perf or PerfOpts())
