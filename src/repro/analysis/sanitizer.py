"""Runtime shadow-sanitizer for the disaggregated pools (DESIGN.md §12).

``PoolSanitizer`` is a ``core.hooks.CoreHooks`` implementation that
mirrors every page/slab/refcount/swap/reserve transition the pools
report and cross-checks the pools' actual state against the accounting
invariants the prose rules promise — the ASan analogue for the
CrossPool memory model.  MemServe-style elastic pools break precisely
here: a page freed twice, a refcount that drifts from its holder count,
a swap slot aliased by two requests — all silent until a later request
reads someone else's KV.

Two layers of checking:

  * **per-event** (every hook call): shadow counters accumulate the
    hook payloads and reconcile against the owning pool's own stat
    counters (SAN07).  The hook contract says counters are consistent
    when the hook fires, so any drift means a counter was bumped
    without its hook (or vice versa) — the runtime complement of lint
    rule CP003.
  * **structural** (``audit()``, called by the engine at quiescent
    points — end of ``submit``/``step`` — and by tests directly): a
    full walk of the free lists, request page tables, prefix-tree
    holds, swap tier, refcounts, arena residencies and admission pins.
    Structural audits do NOT run inside hook callbacks: a hook fires
    when its OWNING object is consistent, but a cross-object handoff
    (e.g. the prefix tree swapping a chunk out through the
    virtualizer) is mid-flight at that instant by design.

Rule ids (each raises :class:`PoolSanitizerError` with ``.rule`` set):

  SAN01  page aliasing / double-free (a page both free and mapped, a
         duplicated free-list entry, or a `-1` padding sentinel inside
         a request's own table)
  SAN02  page-conservation violation (free + mapped != budget)
  SAN03  refcount drift (``page_refs`` != actual holder count)
  SAN04  swap-tier accounting violation (slot aliased/leaked, or
         ``swapped_now`` != swapped entries)
  SAN05  reserve/commit pairing violation (ragged layer tables, or a
         table shorter than the committed token count needs)
  SAN06  unpin-before-finish (a model with admitted in-flight requests
         lost its arena pin)
  SAN07  hook/counter adjacency drift (shadow sums != pool counters)
  SAN08  arena slab aliasing or conservation violation

Attach via ``EngineConfig(sanitize=True)`` or ``CROSSPOOL_SANITIZE=1``
(the CI tier-1 leg); detached, the engine does zero extra work.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.core.hooks import CoreHooks
from repro.core.virtualizer import _SWAP_BASE, _swap_decode


class PoolSanitizerError(RuntimeError):
    """One violated pool invariant; ``.rule`` is the SANxx id."""

    def __init__(self, rule: str, message: str):
        self.rule = rule
        super().__init__(f"{rule}: {message}")


class PoolSanitizer(CoreHooks):
    """Shadow state + invariant auditor over the live pool objects."""

    def __init__(self, virt, arena=None, admission=None, cache=None):
        self.virt = virt
        self.arena = arena
        # NB: named ``adm`` — ``self.admission`` would shadow the
        # ``admission`` hook method inherited from CoreHooks
        self.adm = admission
        self.cache = cache
        self.events = 0                 # hook events seen
        self.audits = 0                 # structural audits run
        # shadow accumulators (filled from hook payloads only)
        self.shadow: Dict[str, int] = {
            "kv_swap_out": 0, "kv_swap_in": 0, "kv_reserved": 0,
            "kv_trimmed": 0, "kv_resizes": 0,
            "arena_activations": 0, "arena_evictions": 0,
            "arena_resizes": 0, "cache_evict_pages": 0,
            "cache_fault_pages": 0, "rebalances": 0,
        }
        # baseline: attach may happen after pool construction, so shadow
        # sums reconcile against the DELTA of each counter
        self._base: Dict[str, int] = {
            "swap_out_pages": virt.swap_out_pages,
            "swap_in_pages": virt.swap_in_pages,
            "resizes": virt.resizes,
        }
        if arena is not None:
            self._base.update({
                "activations": arena.activations,
                "evictions": arena.evictions,
                "arena_resizes": arena.resizes,
            })

    # ------------------------------------------------------------------
    # failure reporting
    # ------------------------------------------------------------------
    def _fail(self, rule: str, message: str) -> None:
        raise PoolSanitizerError(rule, message)

    # ------------------------------------------------------------------
    # hook points: shadow accumulation + counter reconciliation (SAN07)
    # ------------------------------------------------------------------
    def _reconcile(self, what: str, counter: int, base_key: str,
                   shadow_key: str) -> None:
        expect = self._base.get(base_key, 0) + self.shadow[shadow_key]
        if counter != expect:
            self._fail(
                "SAN07",
                f"{what}: pool counter is {counter} but hooks account for "
                f"{expect} (base {self._base.get(base_key, 0)} + shadow "
                f"{self.shadow[shadow_key]}) — a mutation bypassed its "
                f"hook, or a hook fired without its counter")

    def kv_swap_out(self, pages: int) -> None:
        self.events += 1
        self.shadow["kv_swap_out"] += pages
        self._reconcile("kv swap-out pages", self.virt.swap_out_pages,
                        "swap_out_pages", "kv_swap_out")

    def kv_swap_in(self, pages: int) -> None:
        self.events += 1
        self.shadow["kv_swap_in"] += pages
        self._reconcile("kv swap-in pages", self.virt.swap_in_pages,
                        "swap_in_pages", "kv_swap_in")

    def kv_reserved(self, pages: int) -> None:
        self.events += 1
        self.shadow["kv_reserved"] += pages

    def kv_trimmed(self, pages: int) -> None:
        self.events += 1
        self.shadow["kv_trimmed"] += pages
        if self.shadow["kv_trimmed"] > self.shadow["kv_reserved"]:
            self._fail(
                "SAN05",
                f"commit_decode_block trimmed "
                f"{self.shadow['kv_trimmed']} pages but only "
                f"{self.shadow['kv_reserved']} were ever reserved — "
                f"unpaired reserve/commit")

    def kv_resize(self, old_pages: int, new_pages: int, swapped_out: int,
                  moved: int) -> None:
        self.events += 1
        self.shadow["kv_resizes"] += 1
        self._reconcile("kv resizes", self.virt.resizes, "resizes",
                        "kv_resizes")
        if self.virt.page_budget != new_pages:
            self._fail(
                "SAN07",
                f"kv_resize reported new budget {new_pages} but the pool "
                f"holds {self.virt.page_budget}")

    def arena_activate(self, model: str, slabs: int) -> None:
        self.events += 1
        self.shadow["arena_activations"] += 1
        if self.arena is not None:
            self._reconcile("arena activations", self.arena.activations,
                            "activations", "arena_activations")

    def arena_evict(self, model: str, slabs: int) -> None:
        self.events += 1
        self.shadow["arena_evictions"] += 1
        if self.arena is not None:
            self._reconcile("arena evictions", self.arena.evictions,
                            "evictions", "arena_evictions")

    def arena_resize(self, old_slots: int, new_slots: int, evicted: int,
                     moved: int) -> None:
        self.events += 1
        self.shadow["arena_resizes"] += 1
        if self.arena is not None:
            self._reconcile("arena resizes", self.arena.resizes,
                            "arena_resizes", "arena_resizes")

    def cache_evict(self, pages: int) -> None:
        self.events += 1
        self.shadow["cache_evict_pages"] += pages

    def cache_fault(self, pages: int) -> None:
        self.events += 1
        self.shadow["cache_fault_pages"] += pages

    def rebalance(self, decision) -> None:
        self.events += 1
        self.shadow["rebalances"] += 1

    # remaining hooks only count events (no reconcilable pool counter)
    def arena_upload(self, model: str, slabs: int) -> None:
        self.events += 1

    def admission(self, model: str, outcome: str, blocker: str) -> None:
        self.events += 1

    def admission_wait(self, model: str, seconds: float) -> None:
        self.events += 1

    def cache_hit(self, model: str, tokens: int) -> None:
        self.events += 1

    def cache_miss(self, model: str) -> None:
        self.events += 1

    # ------------------------------------------------------------------
    # structural audit (quiescent points)
    # ------------------------------------------------------------------
    def audit(self) -> None:
        """Full invariant walk; raises on the first violation."""
        self.audits += 1
        self._audit_kv()
        self._audit_swap_tier()
        self._audit_reservations()
        if self.arena is not None:
            self._audit_arena()
            self._audit_pins()

    # -- KV pages -------------------------------------------------------
    def _holders(self) -> Dict[int, int]:
        """device page id -> number of live holders (request table
        entries + one for a prefix-tree hold)."""
        holders: Dict[int, int] = {}
        for rid, req in self.virt.requests.items():
            for tab in list(req.tables) + [req.state_pages]:
                for p in tab:
                    if p == -1:
                        self._fail(
                            "SAN01",
                            f"request {rid} has a -1 entry in its own "
                            f"table — the batch-padding sentinel must "
                            f"never be mapped")
                    if p >= 0:
                        holders[p] = holders.get(p, 0) + 1
        cache = self.cache or self.virt.cache_provider
        if cache is not None:
            for p in cache.device_pages():
                holders[p] = holders.get(p, 0) + 1
        return holders

    def _audit_kv(self) -> None:
        virt = self.virt
        free = virt.free_list
        budget = virt.page_budget
        free_set = set(free)
        if len(free_set) != len(free):
            dup = sorted(p for p in free_set if free.count(p) > 1)
            self._fail("SAN01",
                       f"double-free: page(s) {dup} appear more than once "
                       f"on the free list")
        bad = [p for p in free if not 0 <= p < budget]
        if bad:
            self._fail("SAN01",
                       f"free list holds out-of-range page id(s) {bad} "
                       f"(budget {budget})")
        holders = self._holders()
        aliased = sorted(free_set & holders.keys())
        if aliased:
            self._fail("SAN01",
                       f"page(s) {aliased} are simultaneously free and "
                       f"mapped — use-after-free in the making")
        oob = sorted(p for p in holders if not 0 <= p < budget)
        if oob:
            self._fail("SAN01",
                       f"mapped page id(s) {oob} outside [0, {budget})")
        if len(free_set) + len(holders) != budget:
            self._fail(
                "SAN02",
                f"page conservation broken: {len(free_set)} free + "
                f"{len(holders)} mapped != budget {budget} "
                f"(leaked or conjured pages)")
        # refcounts: page_refs must equal the holder count for every
        # mapped page; _refs may only name mapped, actually-shared pages
        for p, n in holders.items():
            refs = virt.page_refs(p)
            if refs != n:
                self._fail(
                    "SAN03",
                    f"refcount drift on page {p}: page_refs={refs} but "
                    f"{n} live holder(s) map it")
        stale = sorted(p for p in virt._refs if p not in holders)
        if stale:
            self._fail("SAN03",
                       f"_refs tracks page(s) {stale} that no holder maps")

    # -- swap tier ------------------------------------------------------
    def _swapped_slots(self) -> List[int]:
        slots: List[int] = []
        for req in self.virt.requests.values():
            for _, _, slot in req.swapped_entries():
                slots.append(slot)
        cache = self.cache or self.virt.cache_provider
        if cache is not None and hasattr(cache, "_walk"):
            for node in cache._walk():
                if node.swapped:
                    slots.extend(_swap_decode(p) for p in node.pages
                                 if p <= _SWAP_BASE)
        return slots

    def _audit_swap_tier(self) -> None:
        virt = self.virt
        used = self._swapped_slots()
        used_set = set(used)
        if len(used_set) != len(used):
            dup = sorted(s for s in used_set if used.count(s) > 1)
            self._fail("SAN04",
                       f"swap slot(s) {dup} aliased by multiple entries")
        free_set = set(virt.swap_free)
        if len(free_set) != len(virt.swap_free):
            self._fail("SAN04", "duplicate entries on the swap free list")
        both = sorted(used_set & free_set)
        if both:
            self._fail("SAN04",
                       f"swap slot(s) {both} simultaneously free and used")
        cap = 0 if virt.swap_buffer is None else len(virt.swap_buffer)
        oob = sorted(s for s in used_set | free_set if not 0 <= s < cap)
        if oob:
            self._fail("SAN04",
                       f"swap slot id(s) {oob} outside the {cap}-slot tier")
        if virt.swapped_now != len(used):
            self._fail(
                "SAN04",
                f"swapped_now={virt.swapped_now} but {len(used)} swapped "
                f"entries exist across requests and the prefix tree")

    # -- reserve/commit pairing ----------------------------------------
    def _audit_reservations(self) -> None:
        for rid, req in self.virt.requests.items():
            view = self.virt.views[req.model]
            if not view.n_kv_layers:
                continue
            lens = {len(t) for t in req.tables}
            if len(lens) > 1:
                self._fail(
                    "SAN05",
                    f"request {rid} has ragged layer tables {sorted(lens)} "
                    f"— a reserve or trim touched only some layers")
            have = len(req.tables[0]) if req.tables else 0
            need = math.ceil(max(req.tokens, 1) / view.tokens_per_page)
            if have < need:
                self._fail(
                    "SAN05",
                    f"request {rid} committed {req.tokens} tokens needing "
                    f"{need} chunks/layer but maps only {have} — a commit "
                    f"outran its reservation")

    # -- arena ----------------------------------------------------------
    def _audit_arena(self) -> None:
        arena = self.arena
        resident: Dict[int, str] = {}
        for name, res in arena.residency.items():
            for s in res.slots.ravel():
                s = int(s)
                if s in resident:
                    self._fail(
                        "SAN08",
                        f"slab {s} mapped by both {resident[s]!r} and "
                        f"{name!r}")
                resident[s] = name
        free = arena.free_list
        free_set = set(free)
        if len(free_set) != len(free):
            self._fail("SAN08", "duplicate entries on the arena free list")
        both = sorted(free_set & resident.keys())
        if both:
            self._fail("SAN08",
                       f"slab(s) {both} simultaneously free and resident")
        oob = sorted(s for s in free_set | resident.keys()
                     if not 0 <= s < arena.slot_budget)
        if oob:
            self._fail("SAN08",
                       f"slab id(s) {oob} outside [0, {arena.slot_budget})")
        if len(free_set) + len(resident) != arena.slot_budget:
            self._fail(
                "SAN08",
                f"slab conservation broken: {len(free_set)} free + "
                f"{len(resident)} resident != budget {arena.slot_budget}")

    def _audit_pins(self) -> None:
        if self.adm is None:
            return
        for model, count in self.adm.inflight.items():
            if count <= 0 or model not in self.arena.views:
                continue
            pins = self.arena.pins.get(model, 0)
            if pins < count:
                self._fail(
                    "SAN06",
                    f"model {model!r} has {count} admitted in-flight "
                    f"request(s) but only {pins} arena pin(s) — an unpin "
                    f"ran before finish, its weights are evictable "
                    f"mid-request")

    # ------------------------------------------------------------------
    def report(self) -> Dict[str, int]:
        return {"events": self.events, "audits": self.audits,
                **self.shadow}
