"""Observability hook points for the core pool layer (DESIGN.md §10).

The pools (``virtualizer``, ``weight_pool``), the admission controller
and the elastic rebalancer each hold an optional ``hooks`` attribute.
When it is ``None`` (the default) every hook site is a single
``is not None`` check — the disabled path does no calls and no
allocations.  When a :class:`CoreHooks` implementation is attached
(``runtime.observe.EngineObserver`` is the canonical one), the core
layer reports its state transitions WITHOUT importing anything from the
runtime layer — this module is the whole dependency surface.

Hook ordering guarantees (what an implementation may rely on):

  * hooks fire AFTER the state change they describe has fully applied
    (counters read through the owning object are already consistent);
  * hooks fire on the engine host thread, never from inside a jitted
    program — implementations may allocate and may raise only at the
    cost of aborting the step;
  * a hook is never invoked with a zero-sized change (``kv_swap_out(0)``
    etc. are elided at the call site).
"""
from __future__ import annotations


class CoreHooks:
    """No-op base: every method is a hook point, override what you need."""

    # --- KV virtualizer (swap tier + reserve/commit + live resize) -----
    def kv_swap_out(self, pages: int) -> None:
        """``pages`` page rows moved device -> host swap tier."""

    def kv_swap_in(self, pages: int) -> None:
        """``pages`` page rows faulted host -> device (``ensure_resident``)."""

    def kv_reserved(self, pages: int) -> None:
        """``pages`` pre-mapped for a decode block (``reserve_decode_block``)."""

    def kv_trimmed(self, pages: int) -> None:
        """Unused reserved ``pages`` returned (``commit_decode_block``)."""

    def kv_resize(self, old_pages: int, new_pages: int,
                  swapped_out: int, moved: int) -> None:
        """The page pool was live-resized (elastic boundary move)."""

    # --- weights arena -------------------------------------------------
    def arena_activate(self, model: str, slabs: int) -> None:
        """A cold model's ``slabs`` were mapped into the arena."""

    def arena_evict(self, model: str, slabs: int) -> None:
        """A resident model's ``slabs`` were returned to the free list."""

    def arena_upload(self, model: str, slabs: int) -> None:
        """``slabs`` slab rows were uploaded host -> device."""

    def arena_resize(self, old_slots: int, new_slots: int,
                     evicted: int, moved: int) -> None:
        """The arena was live-resized (elastic boundary move)."""

    # --- admission front door ------------------------------------------
    def admission(self, model: str, outcome: str, blocker: str) -> None:
        """One admission verdict: ``outcome`` in admitted/queued/rejected,
        ``blocker`` in ''/'pages'/'weights' (what deferred a queue)."""

    def admission_wait(self, model: str, seconds: float) -> None:
        """A queued request drained after ``seconds`` at the front door."""

    # --- prefix cache (DESIGN.md §11) ----------------------------------
    def cache_hit(self, model: str, tokens: int) -> None:
        """A cache-eligible admission reused ``tokens`` cached prompt
        tokens (fires once per admitted request with a non-empty match)."""

    def cache_miss(self, model: str) -> None:
        """A cache-eligible admission found no reusable prefix."""

    def cache_evict(self, pages: int) -> None:
        """``pages`` device pages left the tree's hold (LRU eviction, or
        a shed to the second-chance swap tier)."""

    def cache_fault(self, pages: int) -> None:
        """``pages`` shed pages faulted back from the swap tier on a
        second-chance hit."""

    # --- elastic rebalancer --------------------------------------------
    def rebalance(self, decision) -> None:
        """One applied boundary move (a ``RebalanceDecision``)."""

    # --- SLO engine (DESIGN.md §13) ------------------------------------
    def slo_breach(self, breach) -> None:
        """A multi-rate burn-rate breach (a ``runtime.observe.SLOBreach``;
        held loosely typed so the core layer stays runtime-import-free).
        Fires once per (model, metric) on the breaching EDGE — re-arms
        only after the condition clears."""


class CompositeHooks(CoreHooks):
    """Fan one hook stream out to several sinks, in attachment order.

    The engine uses this when more than one consumer wants the core
    events (e.g. the ``EngineObserver`` plus the shadow sanitizer,
    ``repro.analysis.sanitizer.PoolSanitizer``).  Sinks are invoked in
    order; a raising sink aborts the step like any single hook would
    (the sanitizer RELIES on that — a detected violation must surface,
    not be swallowed so later sinks still run)."""

    def __init__(self, *sinks: CoreHooks):
        self.sinks = [s for s in sinks if s is not None]

    def _fan(self, name, *args):
        for s in self.sinks:
            getattr(s, name)(*args)

    def kv_swap_out(self, pages):
        self._fan("kv_swap_out", pages)

    def kv_swap_in(self, pages):
        self._fan("kv_swap_in", pages)

    def kv_reserved(self, pages):
        self._fan("kv_reserved", pages)

    def kv_trimmed(self, pages):
        self._fan("kv_trimmed", pages)

    def kv_resize(self, old_pages, new_pages, swapped_out, moved):
        self._fan("kv_resize", old_pages, new_pages, swapped_out, moved)

    def arena_activate(self, model, slabs):
        self._fan("arena_activate", model, slabs)

    def arena_evict(self, model, slabs):
        self._fan("arena_evict", model, slabs)

    def arena_upload(self, model, slabs):
        self._fan("arena_upload", model, slabs)

    def arena_resize(self, old_slots, new_slots, evicted, moved):
        self._fan("arena_resize", old_slots, new_slots, evicted, moved)

    def admission(self, model, outcome, blocker):
        self._fan("admission", model, outcome, blocker)

    def admission_wait(self, model, seconds):
        self._fan("admission_wait", model, seconds)

    def cache_hit(self, model, tokens):
        self._fan("cache_hit", model, tokens)

    def cache_miss(self, model):
        self._fan("cache_miss", model)

    def cache_evict(self, pages):
        self._fan("cache_evict", pages)

    def cache_fault(self, pages):
        self._fan("cache_fault", pages)

    def rebalance(self, decision):
        self._fan("rebalance", decision)

    def slo_breach(self, breach):
        self._fan("slo_breach", breach)
