"""Admission control: per-model queues enforcing the planner's budgets.

Paper §3.1: "if the pool page budget is exhausted, admission control queues
or rejects new requests instead of interrupting active decode requests."
Active pages are never revoked; shedding happens only at admission.

Since prefill runs through the weights arena too, admission is
ARENA-AWARE: a request for a cold model implies ``total_slabs`` of upload
traffic (``weight_pool.slabs_for_config`` of it, computed from the packed
view), and admitting it would evict resident models LRU.  ``try_admit``
therefore also checks that the cold model's slabs are reachable WITHOUT
revoking a model that is pinned or has controller-tracked in-flight
requests — a burst of cold-model arrivals queues at the front door instead
of thrashing the arena's LRU between models that both still have work.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.core.virtualizer import KVVirtualizer
from repro.core.weight_pool import OutOfSlabsError


@dataclass
class PendingRequest:
    request_id: int
    model: str
    prompt_tokens: int
    expected_output: int
    arrival_time: float
    enqueue_time: float = 0.0
    # prefix-cache admission inputs (DESIGN.md §11): the engine fills
    # ``prompt_ids`` (real token content — synthetic prompts stay None and
    # are silently cache-cold), ``cache`` (the request's opt-out) and
    # ``bucket`` (the prompt's prefill bucket, the cache key's shape half)
    prompt_ids: Optional[np.ndarray] = None
    cache: bool = True
    bucket: int = 0
    # prefix-cache admission OUTPUTS (set by ``try_admit`` on success):
    # the fork point (cached tokens mapped from the tree) and the cached
    # prefix's captured per-token MoE routing [fork, L, k] (None = dense)
    cached_tokens: int = 0
    prefix_routes: Optional[np.ndarray] = None


@dataclass
class ModelAdmissionStats:
    """Per-model admitted/queued/rejected counters."""

    admitted: int = 0
    queued: int = 0
    rejected: int = 0


@dataclass
class AdmissionStats:
    admitted: int = 0
    queued: int = 0
    rejected: int = 0
    queue_wait_total: float = 0.0
    # admissions deferred purely by weights-arena pressure (cold-model burst)
    weight_pressure_queued: int = 0
    # admissions deferred by KV-page pressure (the rebalancer's grow signal)
    page_pressure_queued: int = 0
    per_model: Dict[str, ModelAdmissionStats] = field(default_factory=dict)

    def bump(self, model: str, outcome: str) -> None:
        """Count one admission outcome globally AND for ``model``."""
        setattr(self, outcome, getattr(self, outcome) + 1)
        m = self.per_model.setdefault(model, ModelAdmissionStats())
        setattr(m, outcome, getattr(m, outcome) + 1)


class AdmissionController:
    """Queue-or-reject front door for the shared KV pool + weights arena."""

    def __init__(self, virtualizer: KVVirtualizer, *, arena=None,
                 max_queue_per_model: int = 64,
                 reserve_output_tokens: bool = True):
        self.virt = virtualizer
        self.arena = arena              # WeightArena or None (KV-only mode)
        self.max_queue = max_queue_per_model
        self.reserve_output = reserve_output_tokens
        self.queues: Dict[str, Deque[PendingRequest]] = collections.defaultdict(
            collections.deque)
        # admitted-but-unfinished request count per model: the controller's
        # view of which models still have work in flight (the engine calls
        # ``finish`` as requests complete).  Admission also takes the
        # arena PIN for the request (released by ``finish``), so the LRU
        # eviction planner can never pick a model whose weights an
        # admitted request still needs — the capacity check below and the
        # victim selection in ``WeightArena._plan_evictions`` enforce the
        # same protected set.
        self.inflight: Dict[str, int] = collections.defaultdict(int)
        self._last_block: str = ""      # "pages" | "weights" | "" (admitted)
        # the elastic rebalancer's pressure signal: free pages held back
        # from admission (swap-tier fault-in headroom / pending-shrink
        # reservation).  Verdicts always read the LIVE budgets — the pool
        # objects are resized in place — and this reserve on top of them.
        self.reserve_pages: int = 0
        # prefix cache (core.prefix_cache.PrefixCache) — when attached,
        # ``try_admit`` becomes cache-aware: cached tokens cost zero new
        # pages, cached-but-swapped tokens cost fault-in pages, and the
        # verdict still honors ``reserve_pages``
        self.cache = None
        self.stats = AdmissionStats()
        # optional observability sink (core.hooks.CoreHooks); hook calls
        # mirror the ``stats.bump`` sites one-for-one, so the exported
        # admission counters can never disagree with AdmissionStats
        self.hooks = None

    def offer(self, req: PendingRequest, now: float) -> str:
        """Returns 'admitted' | 'queued' | 'rejected'."""
        if self.try_admit(req):
            self.stats.bump(req.model, "admitted")
            if self.hooks is not None:
                self.hooks.admission(req.model, "admitted", "")
            return "admitted"
        if len(self.queues[req.model]) < self.max_queue:
            req.enqueue_time = now
            self.queues[req.model].append(req)
            self.stats.bump(req.model, "queued")
            if self._last_block == "weights":
                # counted ONCE per deferred request, here — not on drain
                # retries and not for rejections
                self.stats.weight_pressure_queued += 1
            elif self._last_block == "pages":
                self.stats.page_pressure_queued += 1
            if self.hooks is not None:
                self.hooks.admission(req.model, "queued", self._last_block)
            return "queued"
        self.stats.bump(req.model, "rejected")
        if self.hooks is not None:
            self.hooks.admission(req.model, "rejected", "")
        return "rejected"

    # ------------------------------------------------------------------
    def _weights_pressure_ok(self, model: str) -> bool:
        """Whether admitting a request for ``model`` fits the arena without
        revoking weights another admitted request still needs.

        Reachable slabs = free + resident models that are neither pinned
        nor tracked in flight by this controller.  A resident or
        arena-less (fused fallback) model always passes.
        """
        arena = self.arena
        if arena is None or model not in arena.views:
            return True
        if arena.is_resident(model):
            return True
        need = arena.views[model].total_slabs
        if need > arena.slot_budget:
            # a budget error, not pressure: NO admission can ever serve
            # this model — fail loudly instead of queueing forever
            raise OutOfSlabsError(
                f"model {model!r} needs {need} slabs but the arena budget "
                f"is {arena.slot_budget}; raise slot_budget or drop the "
                f"model from the colocation set")
        reachable = arena.free_slabs + sum(
            arena.views[name].total_slabs
            for name in arena.residency
            if name not in arena.pins and not self.inflight.get(name))
        # slabs already promised to OTHER admitted cold models that have
        # not activated yet (their upload lands between now and prefill)
        promised = sum(
            arena.views[name].total_slabs
            for name, count in self.inflight.items()
            if count and name != model and name in arena.views
            and not arena.is_resident(name))
        return need <= reachable - promised

    def try_admit(self, req: PendingRequest) -> bool:
        """Admit iff BOTH budgets hold: KV pages for prompt (+ reserved
        output) AND weights-arena reachability for a cold model.

        Admission takes the request's arena PIN (released by ``finish``),
        so from this moment the model's weights can never be picked as an
        LRU eviction victim — including the window between admission and
        the prefill that makes the model resident.

        With a prefix cache attached, a cache-eligible request first
        probes the tree: the matched prefix's device-resident full
        chunks become a page-count DISCOUNT (they map read-only, costing
        zero new pages), swapped chunks keep their cold cost (fault-in
        takes a fresh page each) and a swapped copy-on-write SOURCE adds
        a surcharge on top.  Only after the discounted verdict AND the
        weights check pass does the request fault swapped chunks in and
        register with the shared mapping."""
        expect = req.expected_output if self.reserve_output else 0
        cache = self.cache
        view = self.virt.views.get(req.model)
        eligible = (cache is not None and req.cache
                    and req.prompt_ids is not None
                    and view is not None and view.n_kv_layers > 0
                    and 0 < req.prompt_tokens <= req.bucket)
        fork, nodes, n_full, rem, discount = 0, [], 0, 0, 0
        if eligible:
            matched, nodes = cache.match_prefix(req.model, req.bucket,
                                                req.prompt_ids)
            # keep at least one uncached token: the suffix pass is what
            # produces the first output logits
            fork = min(matched, req.prompt_tokens - 1)
            if fork > 0 and self.virt.configs[req.model].is_moe and any(
                    n.routes is None for n in nodes):
                fork = 0      # MoE needs the routing to replay exactly
            if fork > 0:
                L = view.n_kv_layers
                tpp = view.tokens_per_page
                n_full, rem = fork // tpp, fork % tpp

        def _discount() -> int:
            if fork == 0:
                return 0
            resident_full = sum(
                1 for n in nodes[:n_full] if not n.swapped)
            cow_swapped = rem and nodes[n_full].swapped
            return view.n_kv_layers * resident_full \
                - (view.n_kv_layers if cow_swapped else 0)

        discount = _discount()
        deficit = self.virt.admission_deficit(
            req.model, req.prompt_tokens, expect,
            reserve=self.reserve_pages, discount_pages=discount)
        if deficit > 0 and cache is not None:
            # the tree's refcount-0 LRU pages are reclaimable capacity:
            # shed them (to the second-chance swap tier when enabled)
            # before letting cache retention queue a request the
            # cache-off engine would have admitted.  Shedding may swap
            # chunks this very match relies on, so the discount is
            # recomputed from the nodes' live state before the re-check.
            cache.shed(deficit)
            discount = _discount()
            deficit = self.virt.admission_deficit(
                req.model, req.prompt_tokens, expect,
                reserve=self.reserve_pages, discount_pages=discount)
        if deficit > 0:
            self._last_block = "pages"
            return False
        if not self._weights_pressure_ok(req.model):
            self._last_block = "weights"
            return False
        self._last_block = ""
        if fork > 0:
            used = nodes[:n_full + (1 if rem else 0)]
            cache.fault_chunks(used)
            self.virt.register_request_with_prefix(
                req.request_id, req.model, req.prompt_tokens,
                [n.pages for n in nodes[:n_full]],
                nodes[n_full].pages if rem else None)
            routes = [n.routes for n in used]
            if routes and all(r is not None for r in routes):
                req.prefix_routes = np.concatenate(routes, axis=0)[:fork]
        else:
            self.virt.register_request(req.request_id, req.model,
                                       req.prompt_tokens)
        req.cached_tokens = fork
        if eligible:
            # fires once per successful registration — queued-retry
            # probes that fail the budget never double-count
            cache.record_admission(req.model, req.prompt_tokens, fork)
        self.inflight[req.model] += 1
        if self.arena is not None and req.model in self.arena.views:
            self.arena.pin(req.model)
        return True

    def finish(self, model: str) -> None:
        """One of ``model``'s admitted requests completed (or was aborted):
        its pin drops and its weights become reachable for cold
        activations again once the in-flight count reaches zero."""
        n = self.inflight.get(model, 0) - 1
        if n <= 0:
            self.inflight.pop(model, None)
        else:
            self.inflight[model] = n
        if self.arena is not None and model in self.arena.views:
            self.arena.unpin(model)

    def cancel_queued(self, request_id: int) -> bool:
        """Remove a still-queued request from its model's front-door queue.

        Queued requests hold NO resources (``try_admit`` failed before any
        page/pin was taken), so cancellation is pure bookkeeping; admitted
        requests are cancelled through the engine, which releases pages and
        calls :meth:`finish` instead.
        """
        for q in self.queues.values():
            for pending in q:
                if pending.request_id == request_id:
                    q.remove(pending)
                    return True
        return False

    def drain(self, now: float) -> List[PendingRequest]:
        """Admit queued requests that now fit (FIFO per model, round-robin
        across models so one model cannot starve the others)."""
        admitted: List[PendingRequest] = []
        progress = True
        while progress:
            progress = False
            for model in list(self.queues):
                q = self.queues[model]
                if not q:
                    continue
                head = q[0]
                if self.try_admit(head):
                    q.popleft()
                    self.stats.queue_wait_total += now - head.enqueue_time
                    self.stats.bump(model, "admitted")
                    if self.hooks is not None:
                        self.hooks.admission(model, "admitted", "")
                        self.hooks.admission_wait(
                            model, now - head.enqueue_time)
                    admitted.append(head)
                    progress = True
        return admitted

    def queued_count(self) -> int:
        return sum(len(q) for q in self.queues.values())
