"""qwen3-14b — dense Qwen3 [hf:Qwen/Qwen3-8B (family); hf].

Assigned config: 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936,
qk_norm, head_dim=128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17_408,
    vocab_size=151_936,
    attention="gqa",
    qk_norm=True,
    rope_theta=1_000_000.0,
    max_position=131_072,
    source="hf:Qwen/Qwen3-8B family; hf",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8, d_ff=128,
    vocab_size=256, max_position=512,
)
