"""AdamW over param pytrees, with configurable moment dtype.

Moments default to bf16 for the 100B+ configs so (params + grads + m + v)
fits the 16 GiB/chip HBM budget after FSDP sharding (DESIGN.md §5); f32
moments are the default at research scale.  Optimizer state inherits the
parameters' sharding, i.e. ZeRO-style sharded states under pjit for free.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array
    m: Dict
    v: Dict


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"        # "float32" | "bfloat16"
    grad_clip: float = 1.0
    warmup_steps: int = 100

    def _mdt(self):
        return jnp.bfloat16 if self.moment_dtype == "bfloat16" else jnp.float32

    def init(self, params: Dict) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self._mdt())
        return AdamWState(
            count=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def schedule(self, step: jax.Array) -> jax.Array:
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        return self.lr * warm

    def update(self, grads: Dict, state: AdamWState, params: Dict
               ) -> Tuple[Dict, AdamWState]:
        count = state.count + 1
        # global-norm clip in f32
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-12))
        lr = self.schedule(count)
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)
        mdt = self._mdt()

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m32 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            v32 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
            mhat = m32 / b1c
            vhat = v32 / b2c
            step = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:                       # decay matrices only
                step = step + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            return new_p, m32.astype(mdt), v32.astype(mdt)

        flat = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(count, new_m, new_v)
