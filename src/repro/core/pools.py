"""Disaggregated memory pools: the engine-level objects.

``KVCachePool`` owns a device, every colocated model's *non-FFN* params,
and the shared physical KV page pool (virtualizer) — the SINGLE KV
allocation serving every colocated model's decode.  ``WeightsPool`` owns
another device and the consolidated FFN/MoE weights of ALL colocated
models — since PR 2 as ONE demand-managed slab arena
(``repro.core.weight_pool.WeightArena``): master copies stay on the host,
models are activated into / evicted from the arena, and device FFN bytes
are fixed by ``slot_budget`` alone regardless of the colocation count —
the weights-side twin of the KV pool's ``page_budget`` claim.  Hidden
states are the only tensors that cross between the pools (``transfer``),
matching the paper's NVSHMEM boundary.

On a one-device host both pools may map to the same device — the data-path
structure (split params, explicit transfers, page accounting) is identical;
on the production mesh the same roles are expressed by the ``crosspool``
sharding strategy inside one SPMD program.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import split_exec
from repro.core.virtualizer import (DEFAULT_PAGE_BYTES, KVVirtualizer,
                                    ModelView)
from repro.core.weight_pool import (DEFAULT_SLAB_BYTES, ModelArenaView,
                                    OutOfSlabsError, WeightArena)


@dataclass
class PooledModel:
    cfg: ModelConfig
    kv_params: Dict            # embeddings, norms, attention (KV pool device)
    # HOST master FFN tree (numpy leaves) — fused-fallback families only;
    # split models' single host master is the arena's packed slab form
    w_params: Optional[Dict]
    view: ModelView            # how this model types the shared pages
    # how this model's FFN tree maps onto arena slabs (None for fused
    # fallback families, which never read weights through the arena)
    w_view: Optional[ModelArenaView]
    # the ONE shared weights arena (same object for every pooled model)
    arena: Optional[WeightArena]
    # None for fused-fallback families (SSM/hybrid/enc-dec/SWA)
    stage_fns: Optional[split_exec.StageFns]


class WeightsPool:
    """Consolidated FFN weights of all colocated cold models.

    Device side: ONE slab arena sized by ``slot_budget``.  Host side: the
    packed master slabs for arena (split-execution) models — stored ONCE,
    in upload-ready form — plus plain FFN trees (numpy leaves) for the
    fused-fallback families the arena never serves.
    """

    def __init__(self, device, *, slab_bytes: int = DEFAULT_SLAB_BYTES):
        self.device = device
        self.arena = WeightArena(slab_bytes=slab_bytes, device=device)
        # host master trees of the fallback families only (split models'
        # single host copy is the packed arena.host_slabs)
        self.ffn_params: Dict[str, Dict] = {}

    def add_model(self, name: str, cfg: ModelConfig, w_params: Dict) -> None:
        host = jax.tree.map(np.asarray, w_params)
        if split_exec.supports_split(cfg):
            self.arena.add_model(name, cfg, host)
        else:
            self.ffn_params[name] = host

    def finalize(self, slot_budget: Optional[int] = None, *,
                 allocate: bool = True) -> None:
        self.arena.finalize(slot_budget, allocate=allocate)

    def total_bytes(self) -> int:
        """DEVICE weights-pool bytes: the arena, fixed by slot_budget."""
        return self.arena.device_bytes()

    def resize(self, slot_budget: int):
        """Elastic entry: live-resize the arena (DESIGN.md §8)."""
        return self.arena.resize(slot_budget)

    def host_master_bytes(self) -> int:
        return sum(
            leaf.size * leaf.dtype.itemsize
            for tree in self.ffn_params.values()
            for leaf in jax.tree.leaves(tree))


class KVCachePool:
    """Attention-side pool: non-FFN params + the shared paged KV space."""

    def __init__(self, device, models: Dict[str, ModelConfig], *,
                 page_budget: int, page_bytes: int = DEFAULT_PAGE_BYTES,
                 pool_dtype=jnp.bfloat16,
                 allocate_device_pool: bool = True):
        self.device = device
        self.attn_params: Dict[str, Dict] = {}
        self.virtualizer = KVVirtualizer(
            models, page_budget=page_budget, page_bytes=page_bytes,
            dtype=pool_dtype, allocate_device_pool=allocate_device_pool,
            device=device)

    def add_model(self, name: str, kv_params: Dict) -> None:
        self.attn_params[name] = jax.device_put(kv_params, self.device)

    def resize(self, page_budget: int, protected=()):
        """Elastic entry: live-resize the shared page pool (DESIGN.md §8)."""
        return self.virtualizer.resize(page_budget, protected=protected)

    def total_param_bytes(self) -> int:
        return sum(
            leaf.size * leaf.dtype.itemsize
            for tree in self.attn_params.values()
            for leaf in jax.tree.leaves(tree))


def transfer(x: jax.Array, device) -> jax.Array:
    """The pool boundary: explicit async hidden-state transfer."""
    return jax.device_put(x, device)


def build_pools(models: Dict[str, ModelConfig], params: Dict[str, Dict], *,
                kv_device=None, w_device=None, page_budget: int,
                page_bytes: int = DEFAULT_PAGE_BYTES,
                pool_dtype=jnp.bfloat16,
                allocate_device_pool: bool = True,
                slot_budget: Optional[int] = None,
                slab_bytes: int = DEFAULT_SLAB_BYTES,
                arena_device=None,
                allocate_device_arena: Optional[bool] = None,
                activate_resident: bool = True,
                ):
    """Split every model's params across the two pools.

    Models that support split execution get paged :class:`StageFns`
    compiled against the virtualizer's page geometry AND the arena's slab
    geometry; fused-fallback families get ``stage_fns=None`` and keep
    serving through their dense per-model caches.

    ``slot_budget=None`` sizes the arena so every split model fits
    resident at once (the PR-1-equivalent all-resident working set); a
    smaller budget turns activation into demand paging with LRU eviction
    of idle models.  ``activate_resident`` eagerly activates models in
    registration order until the budget is full — remaining models are
    activated on demand by the engine.
    """
    devs = jax.devices()
    kv_device = kv_device or devs[0]
    w_device = w_device or devs[-1]
    kv_pool = KVCachePool(kv_device, models, page_budget=page_budget,
                          page_bytes=page_bytes, pool_dtype=pool_dtype,
                          allocate_device_pool=allocate_device_pool)
    w_pool = WeightsPool(arena_device or w_device, slab_bytes=slab_bytes)
    for name, cfg in models.items():
        kv_tree, w_tree = split_exec.split_params(params[name], cfg)
        kv_pool.add_model(name, kv_tree)
        w_pool.add_model(name, cfg, w_tree)
    if allocate_device_arena is None:
        allocate_device_arena = allocate_device_pool
    w_pool.finalize(slot_budget, allocate=allocate_device_arena)
    if activate_resident:
        for name in w_pool.arena.views:
            try:
                w_pool.arena.activate(name)
            except OutOfSlabsError:
                break                      # the rest activate on demand
    pooled: Dict[str, PooledModel] = {}
    for name, cfg in models.items():
        view = kv_pool.virtualizer.views[name]
        w_view = w_pool.arena.views.get(name)
        stage_fns = (split_exec.make_stage_fns(cfg, view, w_view)
                     if split_exec.supports_split(cfg) else None)
        pooled[name] = PooledModel(
            cfg=cfg,
            kv_params=kv_pool.attn_params[name],
            w_params=w_pool.ffn_params.get(name),
            view=view,
            w_view=w_view,
            arena=w_pool.arena,
            stage_fns=stage_fns,
        )
    return kv_pool, w_pool, pooled
