"""Fig. 7: decode-side TBT P95/P99 on ShareGPT-like traffic, 0.2-1.0 RPS.

Discrete-event simulation of the paper's five-GPU testbed for the three
systems.  Reports per-model P95/P99 TBT and the kvcached/crosspool P99
ratio (the paper reports up to 10.4x at 0.8 RPS).
"""
from __future__ import annotations

import copy

import numpy as np

from repro.configs import PAPER_COLOC_SET, get_config
from repro.runtime import trace as trace_mod
from repro.runtime.request import percentile
from repro.runtime.simulator import DecodeSimulator, paper_placements

RATES = (0.2, 0.4, 0.6, 0.8, 1.0)


def run(csv=print, horizon_s: float = 150.0, seed: int = 0) -> dict:
    models = {n: get_config(n) for n in PAPER_COLOC_SET}
    out = {}
    for rps in RATES:
        proto = trace_mod.make_requests(
            list(models), rps_per_model=rps, horizon_s=horizon_s,
            kind="sharegpt", seed=seed)
        for system in ("static", "kvcached", "crosspool"):
            reqs = copy.deepcopy(proto)
            pl = paper_placements(models, system)
            res = DecodeSimulator(models, pl).run(reqs)
            p95 = percentile(res["tbt"], 95)
            p99 = percentile(res["tbt"], 99)
            out[(system, rps)] = (p95, p99, res["per_model_tbt"])
            csv(f"fig7,{system},rps={rps},p95_ms={p95 * 1e3:.2f},"
                f"p99_ms={p99 * 1e3:.2f},finished={res['finished']}")
    # headline: P99 reduction of crosspool vs kvcached at 0.8 RPS per model
    for rps in (0.8, 1.0):
        for name in models:
            kv = percentile(out[("kvcached", rps)][2][name], 99)
            xp = percentile(out[("crosspool", rps)][2][name], 99)
            if np.isfinite(kv) and np.isfinite(xp) and xp > 0:
                csv(f"fig7,p99_reduction,{name},rps={rps},"
                    f"{kv / xp:.2f}x")
    p99_kv = out[("kvcached", 0.8)][1]
    p99_xp = out[("crosspool", 0.8)][1]
    assert p99_xp < p99_kv, "crosspool must beat kvcached tail at 0.8 RPS"
    return {k: v[:2] for k, v in out.items()}


if __name__ == "__main__":
    run()
