"""KV-cache virtualizer: paged virtualization of one shared physical pool.

TPU adaptation of the paper's CUDA-VMM design (DESIGN.md §2): XLA has no
virtual-memory API, so the pool is ONE pre-allocated device array of
fixed-size pages, and "mapping" is page-table bookkeeping on the host —
identical bytes, identical slow-path/fast-path split:

  * fast path (per token, on device): attention kernels read K/V through a
    page table (``repro.kernels.paged_attention``), writes go to
    (page, slot) coordinates — no allocation on the critical path;
  * slow path (per ~page, on host): ``register_request`` /
    ``extend_request`` / ``release_request`` update the free list and
    per-request page tables against the planner's budget.

Heterogeneity (C1): the pool is untyped (flat elements of one pool dtype).
Each model views a page as ``tokens_per_page(M)`` tokens of ONE layer's K+V
(or MLA latent+rope, or SSM state), so models with different KV layouts
share the same physical pages.  ``tokens_per_page`` = page_elems //
per-token-elems, with the remainder as internal fragmentation — as in any
real pager.

Device-side state is maintained incrementally:

  * writes are ONE jitted scatter per call (``write_tokens`` /
    ``write_prompt_from_cache``) with the pool buffer donated — no
    per-token Python loop, no whole-pool rebind per token;
  * ``batch_tables`` returns a cached ``[n_layers, B, max_pages]`` device
    array and re-uploads only the rows whose page mapping actually changed
    (a request that decodes within its last page does not dirty its row).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.ops import donate_argnums, paged_kv_write

#: The single page-size constant shared by the virtualizer, the pools and
#: the engine.  16 KiB balances internal fragmentation (half a page per
#: request per layer on average) against page-table length for long
#: contexts; it matches the paper's CUDA-VMM granularity choice.
DEFAULT_PAGE_BYTES = 16 * 1024


class OutOfPagesError(RuntimeError):
    pass


@dataclass
class ModelView:
    """How one model interprets physical pages."""

    name: str
    per_token_elems: int          # one layer's K+V (or latent) elems per token
    tokens_per_page: int
    n_kv_layers: int
    kv_shape: Tuple[int, ...]     # per-token per-layer logical shape

    def pages_for(self, tokens: int) -> int:
        """Physical pages to hold ``tokens`` across all KV layers."""
        if self.tokens_per_page == 0:
            return 0
        per_layer = math.ceil(tokens / self.tokens_per_page)
        return per_layer * self.n_kv_layers


def make_view(cfg: ModelConfig, page_elems: int) -> ModelView:
    if cfg.attn_free:
        return ModelView(cfg.name, 0, 0, 0, ())
    if cfg.attention == "mla":
        m = cfg.mla
        per_tok = m.kv_lora_rank + m.qk_rope_head_dim
        shape = (per_tok,)
    else:
        per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
        shape = (2, cfg.n_kv_heads, cfg.head_dim)
    tpp = page_elems // per_tok
    if tpp == 0:
        raise ValueError(
            f"{cfg.name}: per-token KV ({per_tok} elems) exceeds page size "
            f"({page_elems} elems); increase page_bytes")
    return ModelView(cfg.name, per_tok, tpp, cfg.n_decoder_attn_layers, shape)


@dataclass
class RequestPages:
    """Per-request mapping: page_table[layer][chunk] -> physical page id."""

    request_id: int
    model: str
    tokens: int = 0
    tables: List[List[int]] = field(default_factory=list)   # [layer][chunk]
    state_pages: List[int] = field(default_factory=list)    # SSM constant state
    # globally monotonic mapping revision (assigned by the virtualizer):
    # unique per registration AND per page-mapping change, so a reused
    # request id can never alias a stale cached batch table
    rev: int = -1


_POOL_SCATTER = None


def _pool_scatter(pool, kv_flat, pages, slots):
    """One donated-buffer scatter of ``n`` token rows into the flat pool.

    Jitted lazily so importing this module does not initialize the jax
    backend (``donate_argnums`` needs to know it)."""
    global _POOL_SCATTER
    if _POOL_SCATTER is None:
        _POOL_SCATTER = jax.jit(paged_kv_write,
                                donate_argnums=donate_argnums(0))
    return _POOL_SCATTER(pool, kv_flat, pages, slots)


class KVVirtualizer:
    """Host-side pager over one device-resident physical pool."""

    def __init__(self, models: Dict[str, ModelConfig], *,
                 page_budget: int, page_bytes: int = DEFAULT_PAGE_BYTES,
                 dtype=jnp.bfloat16, allocate_device_pool: bool = True,
                 device=None):
        self.page_bytes = page_bytes
        self.dtype = jnp.dtype(dtype)
        self.page_elems = page_bytes // self.dtype.itemsize
        self.page_budget = page_budget
        self.views = {n: make_view(c, self.page_elems)
                      for n, c in models.items()}
        self.configs = dict(models)
        self.free_list: List[int] = list(range(page_budget - 1, -1, -1))
        self.requests: Dict[int, RequestPages] = {}
        self.pool: Optional[jax.Array] = None
        if allocate_device_pool:
            pool = jnp.zeros((page_budget, self.page_elems), dtype)
            # co-locate with the KV pool's attention params (``device`` is
            # KVCachePool's device; None = jax default)
            self.pool = jax.device_put(pool, device) if device is not None \
                else pool
        # incremental device page-table cache: key -> {buf, revs, dev}
        self._batch_cache: Dict[tuple, dict] = {}
        self._rev_counter = 0
        # stats
        self.peak_mapped = 0
        self.map_events = 0
        self.unmap_events = 0

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    @property
    def mapped_pages(self) -> int:
        return self.page_budget - len(self.free_list)

    @property
    def free_pages(self) -> int:
        return len(self.free_list)

    def can_admit(self, model: str, prompt_tokens: int,
                  expected_output: int = 0) -> bool:
        view = self.views[model]
        cfg = self.configs[model]
        need = view.pages_for(prompt_tokens + expected_output) if view.n_kv_layers \
            else 0
        need += math.ceil(cfg.state_bytes_per_request() / self.page_bytes)
        return need <= self.free_pages

    # ------------------------------------------------------------------
    # slow path: map / unmap
    # ------------------------------------------------------------------
    def _next_rev(self) -> int:
        self._rev_counter += 1
        return self._rev_counter

    def _take(self, n: int) -> List[int]:
        """Atomically pop ``n`` pages: raises BEFORE mutating any state."""
        if n > len(self.free_list):
            raise OutOfPagesError(
                f"need {n} pages, {len(self.free_list)} free "
                f"(budget {self.page_budget})")
        pages = [self.free_list.pop() for _ in range(n)]
        self.map_events += n
        self.peak_mapped = max(self.peak_mapped, self.mapped_pages)
        return pages

    def register_request(self, request_id: int, model: str,
                         prompt_tokens: int) -> RequestPages:
        """Map pages for a request's prompt KV (+ SSM state).

        Atomic: the total page count is taken in ONE ``_take``, so an
        ``OutOfPagesError`` leaves the free list untouched (no partially
        mapped request to roll back).
        """
        view = self.views[model]
        cfg = self.configs[model]
        chunks = math.ceil(max(prompt_tokens, 1) / view.tokens_per_page) \
            if view.n_kv_layers else 0
        state_pages = math.ceil(cfg.state_bytes_per_request() / self.page_bytes)
        pages = self._take(chunks * view.n_kv_layers + state_pages)
        req = RequestPages(request_id, model)
        for layer in range(view.n_kv_layers):
            req.tables.append(pages[layer * chunks:(layer + 1) * chunks])
        if state_pages:
            req.state_pages = pages[view.n_kv_layers * chunks:]
        req.tokens = prompt_tokens
        req.rev = self._next_rev()
        self.requests[request_id] = req
        return req

    def pages_needed_for_extend(self, request_id: int,
                                new_tokens: int = 1) -> int:
        """Pages a (would-be) ``extend_request`` would map, without mutating
        anything — lets callers make a multi-request extension atomic by
        checking the batch total against ``free_pages`` first."""
        req = self.requests[request_id]
        view = self.views[req.model]
        if not view.n_kv_layers:
            return 0
        have = len(req.tables[0])
        need = math.ceil(max(req.tokens + new_tokens, 1)
                         / view.tokens_per_page)
        return max(need - have, 0) * view.n_kv_layers

    def extend_request(self, request_id: int, new_tokens: int = 1) -> None:
        """Grow a request by ``new_tokens`` (decode); maps pages on demand.

        Atomic: the pages for every layer are taken in ONE ``_take``, so an
        ``OutOfPagesError`` leaves every layer table at its old (equal)
        length and the token count unchanged.
        """
        req = self.requests[request_id]
        view = self.views[req.model]
        if view.n_kv_layers:
            have = len(req.tables[0])
            need = math.ceil(max(req.tokens + new_tokens, 1)
                             / view.tokens_per_page)
            delta = need - have
            if delta > 0:
                pages = self._take(delta * view.n_kv_layers)
                for layer, tab in enumerate(req.tables):
                    tab.extend(pages[layer * delta:(layer + 1) * delta])
                req.rev = self._next_rev()
        req.tokens += new_tokens

    def release_request(self, request_id: int) -> None:
        req = self.requests.pop(request_id)
        n = 0
        for t in req.tables:
            self.free_list.extend(t)
            n += len(t)
        self.free_list.extend(req.state_pages)
        n += len(req.state_pages)
        self.unmap_events += n

    # ------------------------------------------------------------------
    # fast path: device views
    # ------------------------------------------------------------------
    def page_table_array(self, request_ids: List[int], layer: int,
                         max_pages: int) -> jax.Array:
        """[B, max_pages] int32 physical ids (-1 = unmapped) for one layer."""
        out = np.full((len(request_ids), max_pages), -1, np.int32)
        for i, rid in enumerate(request_ids):
            tab = self.requests[rid].tables[layer]
            out[i, : min(len(tab), max_pages)] = tab[: max_pages]
        return jnp.asarray(out)

    def batch_tables(self, model: str,
                     request_ids: Sequence[Optional[int]],
                     max_pages: int) -> jax.Array:
        """[n_layers, B, max_pages] int32 table for a batch of slots.

        ``None`` entries (empty batch slots) map to all ``-1`` rows.  The
        device array is cached per (model, slot assignment, max_pages) and
        re-uploaded only when a row's page mapping actually changed — a
        request decoding within its current last page reuses the cached
        array with zero host work.
        """
        view = self.views[model]
        key = (model,
               tuple(-1 if r is None else r for r in request_ids),
               max_pages)
        revs = tuple(
            -1 if rid is None or rid not in self.requests
            else self.requests[rid].rev
            for rid in request_ids)
        entry = self._batch_cache.get(key)
        if entry is not None and entry["revs"] == revs:
            return entry["dev"]
        if entry is None:
            buf = np.full((view.n_kv_layers, len(request_ids), max_pages),
                          -1, np.int32)
            old_revs: tuple = (None,) * len(request_ids)
        else:
            buf, old_revs = entry["buf"], entry["revs"]
        for i, rid in enumerate(request_ids):
            if old_revs[i] == revs[i]:
                continue
            buf[:, i, :] = -1
            if rid is not None and rid in self.requests:
                for layer, tab in enumerate(self.requests[rid].tables):
                    m = min(len(tab), max_pages)
                    buf[layer, i, :m] = tab[:m]
        # jnp.array COPIES: jnp.asarray may zero-copy-alias the numpy buffer
        # on CPU, and ``buf`` is mutated in place on later mapping changes —
        # an aliased upload would retroactively corrupt tables already
        # handed to in-flight steps.
        dev = jnp.array(buf)
        if len(self._batch_cache) > 64:     # bound stale slot assignments
            self._batch_cache.clear()
        self._batch_cache[key] = {"buf": buf, "revs": revs, "dev": dev}
        return dev

    def typed_pages(self, model: str) -> jax.Array:
        """The pool viewed as ``[n_pages, tokens_per_page, *kv_shape]``.

        Zero-copy reshape of the shared flat pool; the slack elements at the
        end of each page are invisible to the kernel.
        """
        view = self.views[model]
        used = view.tokens_per_page * view.per_token_elems
        return self.pool[:, :used].reshape(
            (self.page_budget, view.tokens_per_page) + view.kv_shape)

    def _token_coords(self, req: RequestPages, view: ModelView,
                      tokens: np.ndarray, layer: Optional[int] = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(pages, slots) int32 arrays for token indices of one request.

        ``layer=None`` vectorizes over ALL layers: ``tokens`` is [n] and the
        result is [n_layers * n] in layer-major order.
        """
        chunk = tokens // view.tokens_per_page
        slots = (tokens % view.tokens_per_page).astype(np.int32)
        if layer is not None:
            tab = np.asarray(req.tables[layer], np.int32)
            return tab[chunk], slots
        tabs = np.asarray(req.tables, np.int32)        # [L, chunks]
        pages = tabs[:, chunk].reshape(-1)             # [L * n]
        return pages, np.tile(slots, view.n_kv_layers)

    def write_tokens(self, model: str, layer: int, request_id: int,
                     start_token: int, kv: jax.Array) -> None:
        """Write ``kv [n_new, *kv_shape]`` at token offset ``start_token``.

        One jitted, donated-buffer scatter for the whole token range — the
        pool buffer is updated in place rather than rebound per token.
        """
        view = self.views[model]
        req = self.requests[request_id]
        n = kv.shape[0]
        flat = kv.reshape(n, view.per_token_elems)
        toks = np.arange(start_token, start_token + n)
        pages, slots = self._token_coords(req, view, toks, layer)
        self.pool = _pool_scatter(self.pool, flat, jnp.asarray(pages),
                                  jnp.asarray(slots))

    def write_prompt_layer(self, pool: jax.Array, model: str,
                           request_id: int, layer: int, layer_kv,
                           n_tokens: int, batch_index: int = 0) -> jax.Array:
        """Seed ONE layer's prompt KV from full-sequence attention outputs.

        ``layer_kv`` is the per-layer pair a streaming (layer-at-a-time)
        prefill produces: ``(k, v)`` each ``[B,S,KV,hd]`` for GQA or
        ``(latent, rope)`` ``[B,S,·]`` for MLA — the same bytes
        ``write_prompt_from_cache`` scatters, one layer at a time so KV
        lands in the pool while later layers are still executing.

        Pure with respect to the pool: takes and returns the (donated)
        buffer instead of touching ``self.pool``, so a pipeline scheduler
        can thread it through interleaved prefill/decode stages.
        """
        view = self.views[model]
        req = self.requests[request_id]
        a, b = layer_kv
        if len(view.kv_shape) == 1:     # MLA: latent ++ rope on the last axis
            kv = jnp.concatenate([a[batch_index, :n_tokens],
                                  b[batch_index, :n_tokens]], axis=-1)
        else:                           # GQA: [n, 2, KV, hd]
            kv = jnp.stack([a[batch_index, :n_tokens],
                            b[batch_index, :n_tokens]], axis=1)
        flat = kv.reshape(n_tokens, view.per_token_elems)
        toks = np.arange(n_tokens)
        pages, slots = self._token_coords(req, view, toks, layer)
        return _pool_scatter(pool, flat, jnp.asarray(pages),
                             jnp.asarray(slots))

    def write_prompt_from_cache(self, model: str, request_id: int,
                                cache: Dict, n_tokens: int,
                                batch_index: int = 0) -> None:
        """Seed a request's mapped pages from a dense prefill cache.

        ``cache`` is the model's contiguous decode-cache pytree (GQA
        ``{"k","v": [L,B,T,KV,hd]}`` or MLA ``{"latent","rope"}``); tokens
        ``[0, n_tokens)`` of row ``batch_index`` are scattered into the
        request's pages across ALL layers in one device dispatch.
        """
        view = self.views[model]
        req = self.requests[request_id]
        if "k" in cache:
            k = cache["k"][:, batch_index, :n_tokens]      # [L,n,KV,hd]
            v = cache["v"][:, batch_index, :n_tokens]
            kv = jnp.stack([k, v], axis=2)                 # [L,n,2,KV,hd]
        else:
            kv = jnp.concatenate(
                [cache["latent"][:, batch_index, :n_tokens],
                 cache["rope"][:, batch_index, :n_tokens]], axis=-1)
        L = kv.shape[0]
        assert L == view.n_kv_layers, (L, view.n_kv_layers)
        flat = kv.reshape(L * n_tokens, view.per_token_elems)
        toks = np.arange(n_tokens)
        pages, slots = self._token_coords(req, view, toks)
        self.pool = _pool_scatter(self.pool, flat, jnp.asarray(pages),
                                  jnp.asarray(slots))

    # ------------------------------------------------------------------
    def utilization(self) -> Dict[str, float]:
        frag = 0.0
        for rid, req in self.requests.items():
            view = self.views[req.model]
            if not view.n_kv_layers:
                continue
            used = req.tokens * view.per_token_elems * view.n_kv_layers
            held = sum(len(t) for t in req.tables) * self.page_elems
            frag += held - used
        return {
            "mapped_pages": self.mapped_pages,
            "free_pages": self.free_pages,
            "peak_mapped": self.peak_mapped,
            "internal_frag_bytes": frag * self.dtype.itemsize,
        }
