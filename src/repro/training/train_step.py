"""Train step: loss, microbatched gradient accumulation, remat, compression.

``make_train_step`` builds the jittable step the dry-run lowers for every
``train_4k`` cell: cross-entropy (+ MoE load-balance aux), gradients via
``lax.scan`` over microbatches (the activation-memory lever that fits
llama3-405B on 16 GiB chips), optional error-feedback int8 gradient
compression, AdamW update.  All distribution comes from the Strategy's
hooks + in_shardings — the step itself is sharding-agnostic.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.hooks import Hooks, IDENTITY_HOOKS
from repro.models.model import Model
from repro.training import compression
from repro.training.optimizer import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Dict
    opt: AdamWState
    error_fb: Optional[Dict] = None


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross-entropy in f32.  logits [B,S,V], labels [B,S]."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def make_loss_fn(model: Model, *, hooks: Hooks = IDENTITY_HOOKS,
                 aux_weight: float = 0.01, remat: bool = True,
                 extra_inputs: Optional[Callable[[Dict], Dict]] = None):
    def loss_fn(params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        kwargs = extra_inputs(batch) if extra_inputs else {}
        import repro.models.transformer as tfm
        logits, aux = tfm.forward(params, model.cfg, batch["tokens"],
                                  hooks=hooks, remat=remat, **kwargs)
        S_txt = batch["tokens"].shape[1]
        logits_txt = logits[:, -S_txt:, :]          # skip stub-embed prefix
        ce = cross_entropy(logits_txt[:, :-1], batch["tokens"][:, 1:])
        loss = ce + aux_weight * aux
        return loss, {"ce": ce, "aux": aux, "loss": loss}
    return loss_fn


def make_train_step(model: Model, optimizer: AdamW, *,
                    hooks: Hooks = IDENTITY_HOOKS,
                    num_microbatches: int = 1,
                    compress: bool = False,
                    aux_weight: float = 0.01,
                    remat: bool = True,
                    extra_inputs: Optional[Callable[[Dict], Dict]] = None):
    """Returns step(state, batch) -> (state, metrics).

    batch["tokens"]: [global_batch, S].  With ``num_microbatches`` G > 1 the
    batch is split [G, B/G, S] and gradients accumulate through a scan —
    peak activation memory drops Gx while keeping the same global batch.
    """
    loss_fn = make_loss_fn(model, hooks=hooks, aux_weight=aux_weight,
                           remat=remat, extra_inputs=extra_inputs)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        params = state.params
        if num_microbatches == 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            G = num_microbatches

            def mb(b):
                return jax.tree.map(
                    lambda x: x.reshape(G, x.shape[0] // G, *x.shape[1:]), b)

            def acc_body(carry, mb_batch):
                g_acc, m_acc = carry
                (_, metrics), grads = grad_fn(params, mb_batch)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / G, g_acc, grads)
                m_acc = jax.tree.map(lambda a, m: a + m / G, m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            m0 = {"ce": 0.0, "aux": 0.0, "loss": 0.0}
            m0 = jax.tree.map(jnp.float32, m0)
            (grads, metrics), _ = jax.lax.scan(acc_body, (g0, m0), mb(batch))

        error_fb = state.error_fb
        if compress:
            grads, error_fb = compression.compress_grads(grads, error_fb)

        new_params, new_opt = optimizer.update(grads, state.opt, params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return TrainState(new_params, new_opt, error_fb), metrics

    return step


def init_train_state(model: Model, optimizer: AdamW, key, *,
                     compress: bool = False) -> TrainState:
    params = model.init(key)
    return TrainState(
        params=params,
        opt=optimizer.init(params),
        error_fb=compression.init_error_feedback(params) if compress else None,
    )
