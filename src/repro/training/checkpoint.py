"""Sharded checkpointing: save/restore with a host-side index, elastic
reshard across meshes, async save.

Fault-tolerance contract for 1000+ node runs:
  * every leaf is written as its own ``.npy`` plus a JSON index holding
    the tree structure, shapes, dtypes and step — a failed write leaves the
    previous checkpoint intact (write to tmp dir + atomic rename);
  * restore takes TARGET shardings: a checkpoint written on a (16,16) mesh
    restores onto (2,16,16) or a degraded (15,16) mesh (elastic reshard —
    ``jax.device_put`` re-lays every leaf out under the new mesh), which is
    the lose-a-pod recovery path;
  * ``save_async`` moves device->host transfer off the training thread's
    critical path only after the device buffers are snapshot, so training
    can continue while the filesystem write completes.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "___"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def walk(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, path + (str(k),))
        elif hasattr(node, "_fields"):          # NamedTuple: use field names
            for name, v in zip(node._fields, node):
                walk(v, path + (str(name),))
        elif isinstance(node, (tuple, list)):
            for i, v in enumerate(node):
                walk(v, path + (str(i),))
        elif node is None:
            flat[_SEP.join(path) + _SEP + "__none__"] = None
        else:
            flat[_SEP.join(path)] = node

    walk(tree, ())
    return flat


def save(tree, step: int, directory: str) -> str:
    """Synchronous atomic checkpoint write.  Returns the final path."""
    tmp = directory + f".tmp-{step}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    index = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        if leaf is None or key.endswith("__none__"):
            index["leaves"][key] = {"none": True}
            continue
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{abs(hash(key)) % 10 ** 12}_{len(index['leaves'])}.npy"
        np.save(os.path.join(tmp, fname), arr)
        index["leaves"][key] = {"file": fname, "shape": list(arr.shape),
                                "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
    final = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(directory, exist_ok=True)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep=3)
    return final


def save_async(tree, step: int, directory: str) -> threading.Thread:
    """Snapshot device buffers now; write to disk on a worker thread."""
    snapshot = jax.tree.map(
        lambda x: np.asarray(jax.device_get(x)) if x is not None else None,
        tree)
    t = threading.Thread(target=save, args=(snapshot, step, directory),
                         daemon=True)
    t.start()
    return t


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(directory: str, step: Optional[int] = None, *,
            target_tree=None, shardings=None) -> Tuple[Any, int]:
    """Load a checkpoint; optionally re-lay leaves out under ``shardings``
    (elastic reshard onto a different mesh).

    ``target_tree``: pytree with the expected structure (e.g. from
    ``jax.eval_shape``) — used to unflatten.  If None, returns nested dicts.
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "index.json")) as f:
        index = json.load(f)
    flat = {}
    for key, meta in index["leaves"].items():
        if meta.get("none"):
            flat[key.replace(_SEP + "__none__", "")] = None
            continue
        flat[key] = np.load(os.path.join(path, meta["file"]))

    if target_tree is not None:
        ref_flat = _flatten(target_tree)
        ref_keys = {k.replace(_SEP + "__none__", ""): k for k in ref_flat}
        leaves_in_order = []
        paths = jax.tree_util.tree_flatten_with_path(
            target_tree, is_leaf=lambda x: x is None)[0]
        tree_def = jax.tree_util.tree_structure(
            target_tree, is_leaf=lambda x: x is None)
        for p, ref_leaf in paths:
            key = _SEP.join(_path_parts(p))
            val = flat.get(key)
            leaves_in_order.append(val)
        tree = jax.tree_util.tree_unflatten(tree_def, leaves_in_order)
    else:
        tree = _unflatten(flat)

    if shardings is not None:
        tree = jax.tree.map(
            lambda leaf, sh: (jax.device_put(leaf, sh)
                              if leaf is not None else None),
            tree, shardings, is_leaf=lambda x: x is None)
    return tree, step


def _path_parts(path) -> list:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return parts


def _unflatten(flat: Dict[str, Any]):
    root: Dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def _gc(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
