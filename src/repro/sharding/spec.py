"""Logical-axis -> NamedSharding rules.

Params and caches are mapped to :class:`jax.sharding.PartitionSpec` by
*path pattern* rules.  A rule only applies when the dimension is divisible
by the mesh axes it names — otherwise that dim falls back to replicated,
which keeps every (arch x mesh) cell lowerable (uneven vocab/head counts
replicate instead of erroring).
"""
from __future__ import annotations

import fnmatch
import math
from typing import Iterable, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...], None]


def axis_size(mesh: Mesh, axis: AxisName) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def batch_axes(mesh: Mesh) -> AxisName:
    """The pure-DP axes: ('pod','data') on the multi-pod mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def pool_axes(mesh: Mesh) -> AxisName:
    """Axes the consolidated weights pool spans for expert placement."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def safe_spec(mesh: Mesh, shape: Sequence[int], spec: Sequence[AxisName]) -> P:
    """Drop per-dim axes that do not divide the dim size (replicate there)."""
    fixed = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = math.prod(mesh.shape[a] for a in axes)
        fixed.append(ax if dim % size == 0 and dim >= size else None)
    return P(*fixed)


def named(mesh: Mesh, shape: Sequence[int], spec: Sequence[AxisName]
          ) -> NamedSharding:
    return NamedSharding(mesh, safe_spec(mesh, shape, spec))


# ---------------------------------------------------------------------------
# Path-pattern rule tables
# ---------------------------------------------------------------------------

def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


class RuleSet:
    """Ordered (pattern, spec-builder) rules over param/cache path strings.

    The spec is a per-dim axis tuple aligned to the *trailing* dims of the
    array; leading unlisted dims (e.g. the stacked layer dim) replicate.
    """

    def __init__(self, rules: Iterable[Tuple[str, Sequence[AxisName]]],
                 default: Sequence[AxisName] = ()):
        self.rules = list(rules)
        self.default = tuple(default)

    def spec_for(self, mesh: Mesh, path: str, shape: Sequence[int]) -> P:
        for pattern, spec in self.rules:
            if fnmatch.fnmatch(path, pattern):
                return self._align(mesh, shape, spec)
        return self._align(mesh, shape, self.default)

    @staticmethod
    def _align(mesh: Mesh, shape: Sequence[int], spec: Sequence[AxisName]) -> P:
        spec = tuple(spec)
        if len(spec) > len(shape):
            spec = spec[len(spec) - len(shape):]
        full = (None,) * (len(shape) - len(spec)) + spec
        return safe_spec(mesh, shape, full)

    def tree_shardings(self, mesh: Mesh, tree):
        """Pytree of NamedShardings matching ``tree`` (arrays or SDS)."""
        def f(path, leaf):
            return NamedSharding(mesh, self.spec_for(mesh, path_str(path),
                                                     leaf.shape))
        return jax.tree_util.tree_map_with_path(f, tree)

    def tree_specs(self, mesh: Mesh, tree):
        def f(path, leaf):
            return self.spec_for(mesh, path_str(path), leaf.shape)
        return jax.tree_util.tree_map_with_path(f, tree)
