"""Typed errors for the pool-accounting layer (DESIGN.md §12).

``PoolAccountingError`` replaces the bare ``assert``s that used to guard
the virtualizer's and arena's accounting paths: asserts vanish under
``python -O``, which is exactly the mode a production launcher might run
in, and a silently skipped accounting check is the memory-corruption bug
class MemServe/eLLM-style elastic pools break on.  Raising a dedicated
exception type also lets callers (and the shadow sanitizer,
``repro.analysis.sanitizer``) distinguish an accounting-contract
violation from capacity exhaustion (``OutOfPagesError`` /
``OutOfSlabsError``), which is an expected, recoverable outcome.

Lint rule CP007 (``repro.analysis.lint``) guards regressions: a bare
``assert`` in a pool-accounting module fails the static-analysis gate.
"""
from __future__ import annotations


class PoolAccountingError(RuntimeError):
    """An internal pool-accounting invariant was violated.

    Unlike ``OutOfPagesError``/``OutOfSlabsError`` (capacity verdicts a
    caller may catch and retry), this signals a CONTRACT bug — e.g. a
    table write on a swapped request, a retain of a non-device entry, or
    a resize below the 1-page floor — and survives ``python -O``.
    """


def check(cond: bool, message: str) -> None:
    """``assert`` replacement for accounting paths: raises
    :class:`PoolAccountingError` (never elided by ``-O``)."""
    if not cond:
        raise PoolAccountingError(message)
