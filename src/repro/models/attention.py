"""Attention variants: GQA/MHA/MQA, MLA (DeepSeek/MiniCPM3), sliding-window.

Each variant provides ``init_*`` (params), ``*_full`` (whole-sequence, used
by train/prefill) and ``*_decode`` (single-token against a cache).  The
decode cache layouts are exactly what the CrossPool KV-cache pool manages.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.hooks import Hooks, IDENTITY_HOOKS
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def causal_mask(q_pos: jax.Array, k_pos: jax.Array,
                window: int = 0) -> jax.Array:
    """Boolean [.., S, T] mask: True = attend.  ``window``>0 adds locality."""
    m = q_pos[..., :, None] >= k_pos[..., None, :]
    if window > 0:
        m &= q_pos[..., :, None] - k_pos[..., None, :] < window
    return m


# ---------------------------------------------------------------------------
# Core grouped attention
# ---------------------------------------------------------------------------

def attention_core(q: jax.Array, k: jax.Array, v: jax.Array,
                   mask: Optional[jax.Array], scale: float,
                   impl: str = "xla") -> jax.Array:
    """Grouped-query attention.

    q: [B,S,H,D]; k/v: [B,T,KV,D]; mask: broadcastable to [B,KV,G,S,T]
    (pass [B,1,1,S,T] or [1,1,1,S,T]).  Returns [B,S,H,D].
    Softmax statistics in f32.
    """
    if k.dtype.itemsize == 1:           # fp8 KV cache: dequantize on-chip
        k = k.astype(jnp.bfloat16)
        v = v.astype(jnp.bfloat16)
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    if impl == "flash" and mask is None:
        raise ValueError("flash impl requires causal mask semantics")
    qg = q.reshape(B, S, KV, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, v.shape[-1])   # v head dim may differ (MLA)


# ---------------------------------------------------------------------------
# GQA (covers MHA: KV==H, and MQA: KV==1)
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig, dtype) -> Dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], (d, H * hd), dtype),
        "wk": layers.dense_init(ks[1], (d, KV * hd), dtype),
        "wv": layers.dense_init(ks[2], (d, KV * hd), dtype),
        "wo": layers.dense_init(ks[3], (H * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(p: Dict, cfg: ModelConfig, x: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = layers.head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = layers.head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def gqa_full(p: Dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
             *, window: int = 0, hooks: Hooks = IDENTITY_HOOKS,
             kv_positions: Optional[jax.Array] = None,
             kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
             causal: bool = True, impl: str = "xla",
             ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Whole-sequence self-attention (or cross-attention via kv_override).

    Returns (output [B,S,D_model], (k, v) for cache seeding).
    """
    q, k, v = _project_qkv(p, cfg, x)
    if kv_override is not None:
        k, v = kv_override
    kv_pos = positions if kv_positions is None else kv_positions
    if cfg.rope_theta > 0:
        sin_q, cos_q = layers.rope_sin_cos(positions, cfg.head_dim, cfg.rope_theta)
        q = layers.apply_rope(q, sin_q, cos_q)
        if kv_override is None:
            sin_k, cos_k = layers.rope_sin_cos(kv_pos, cfg.head_dim, cfg.rope_theta)
            k = layers.apply_rope(k, sin_k, cos_k)
    q = hooks.attn_q(q)
    k, v = hooks.kv(k), hooks.kv(v)
    scale = cfg.head_dim ** -0.5
    if causal:
        if impl == "flash" and window == 0 and kv_override is None:
            out = kops.flash_attention(q, k, v, scale=scale)
        else:
            mask = causal_mask(positions, kv_pos, window)[:, None, None, :, :]
            out = attention_core(q, k, v, mask, scale)
    else:
        out = attention_core(q, k, v, None, scale)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return hooks.attn_out(out @ p["wo"]), (k, v)


def _pad_to_extent(arr: jax.Array, extent: int) -> jax.Array:
    """Zero-pad or truncate axis 1 to exactly ``extent`` rows.

    The suffix-prefill paths pin their KV reduction extent to the
    PRODUCING pass's bucket so softmax sums run over the identical span:
    padded rows are masked to ``NEG_INF`` (exact 0.0 softmax weight) and
    truncated rows are pad rows no real query attends.
    """
    T = arr.shape[1]
    if T == extent:
        return arr
    if T > extent:
        return arr[:, :extent]
    pad = [(0, 0)] * arr.ndim
    pad[1] = (0, extent - T)
    return jnp.pad(arr, pad)


def gqa_suffix(p: Dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
               prefix_k: jax.Array, prefix_v: jax.Array, kv_extent: int,
               *, hooks: Hooks = IDENTITY_HOOKS,
               ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Suffix-only prefill attention against a cached prompt prefix.

    x: [B,S_suf,D] post-norm hidden of the UNCACHED suffix tokens;
    positions: [B,S_suf] absolute positions (``fork + i``); prefix_k /
    prefix_v: [B,fork,KV,hd] gathered from the pool (post-RoPE, exactly
    the full pass's rows); ``kv_extent``: static KV length = the
    producing pass's prefill bucket.

    Bit-exactness with the full-prompt pass, for every row whose output
    is consumed (absolute position < true prompt length): the suffix
    K/V at those rows reproduce the full pass's (same inputs, same
    per-row math), the concatenated KV is truncated/zero-padded to the
    full pass's reduction extent, and the causal mask over absolute
    positions makes every pad/truncated disagreement masked to the same
    ``NEG_INF`` both sides of the comparison.
    Returns (out [B,S_suf,D_model], (k_suf, v_suf) for pool writing).
    """
    q, k, v = _project_qkv(p, cfg, x)
    if cfg.rope_theta > 0:
        sin, cos = layers.rope_sin_cos(positions, cfg.head_dim, cfg.rope_theta)
        q = layers.apply_rope(q, sin, cos)
        k = layers.apply_rope(k, sin, cos)
    q = hooks.attn_q(q)
    k, v = hooks.kv(k), hooks.kv(v)
    k_all = _pad_to_extent(
        jnp.concatenate([prefix_k.astype(k.dtype), k], axis=1), kv_extent)
    v_all = _pad_to_extent(
        jnp.concatenate([prefix_v.astype(v.dtype), v], axis=1), kv_extent)
    kv_pos = jnp.arange(kv_extent)[None, :]
    mask = causal_mask(positions, kv_pos)[:, None, None, :, :]
    out = attention_core(q, k_all, v_all, mask, cfg.head_dim ** -0.5)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return hooks.attn_out(out @ p["wo"]), (k, v)


def write_kv_cache(cache_k: jax.Array, cache_v: jax.Array,
                   k_new: jax.Array, v_new: jax.Array,
                   lengths) -> Tuple[jax.Array, jax.Array]:
    """Insert one new KV per sequence.

    ``lengths``: scalar (uniform write index — fast path, in-place
    dynamic-update-slice, used by the dry-run decode step) or [B] vector
    (per-request index — engine path at small scale).
    cache: [B,T,KV,hd]; new: [B,1,KV,hd].
    """
    if jnp.ndim(lengths) == 0:
        idx = lengths.astype(jnp.int32) if hasattr(lengths, "astype") else jnp.int32(lengths)
        ck = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype),
                                          (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype),
                                          (0, idx, 0, 0))
        return ck, cv
    T = cache_k.shape[1]
    slot = jnp.arange(T)[None, :] == lengths[:, None]          # [B,T]
    slot = slot[:, :, None, None]
    ck = jnp.where(slot, k_new.astype(cache_k.dtype), cache_k)
    cv = jnp.where(slot, v_new.astype(cache_v.dtype), cache_v)
    return ck, cv


def gqa_decode(p: Dict, cfg: ModelConfig, x: jax.Array,
               cache_k: jax.Array, cache_v: jax.Array, lengths,
               *, hooks: Hooks = IDENTITY_HOOKS, impl: str = "xla",
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a contiguous cache.

    x: [B,1,D]; cache: [B,T,KV,hd]; lengths: scalar or [B] = current context
    length (the new token is written at this index).
    Returns (out [B,1,D], new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    T = cache_k.shape[1]
    q, k, v = _project_qkv(p, cfg, x)
    pos = (jnp.broadcast_to(jnp.asarray(lengths), (B,))[:, None]
           if jnp.ndim(lengths) > 0 else
           jnp.full((B, 1), lengths, dtype=jnp.int32))
    if cfg.rope_theta > 0:
        sin, cos = layers.rope_sin_cos(pos, cfg.head_dim, cfg.rope_theta)
        q = layers.apply_rope(q, sin, cos)
        k = layers.apply_rope(k, sin, cos)
    cache_k, cache_v = write_kv_cache(cache_k, cache_v, k, v, lengths)
    cache_k, cache_v = hooks.kv(cache_k), hooks.kv(cache_v)
    kv_pos = jnp.arange(T)[None, :]
    mask = (kv_pos <= pos)[:, None, None, :, None].swapaxes(-1, -2)  # [B,1,1,1,T]
    scale = cfg.head_dim ** -0.5
    lengths_incl = jnp.broadcast_to(jnp.asarray(lengths) + 1, (B,))
    if hooks.decode_attn is not None:
        # crosspool: sequence-sharded partial-softmax attention over the pool
        out = hooks.decode_attn(q, cache_k, cache_v, lengths_incl)
    elif impl == "paged":
        out = kops.decode_attention(q, cache_k, cache_v, lengths_incl,
                                    scale=scale)
    else:
        out = attention_core(q, cache_k, cache_v, mask, scale)
    out = out.reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return hooks.attn_out(out @ p["wo"]), cache_k, cache_v


def gqa_paged_decode(p: Dict, cfg: ModelConfig, x: jax.Array,
                     pool: jax.Array, page_table: jax.Array, lengths,
                     *, tokens_per_page: int, hooks: Hooks = IDENTITY_HOOKS,
                     impl: Optional[str] = None,
                     ) -> Tuple[jax.Array, jax.Array]:
    """One-token GQA decode against the shared paged KV pool.

    x: [B,1,D]; pool: [n_pages, page_elems] (the untyped physical pool);
    page_table: [B, max_pages] int32 for THIS layer (-1 = unmapped);
    lengths: [B] current context length — the new token's K/V is written at
    (page_table[b, lengths[b] // tpp], lengths[b] % tpp) and attention reads
    lengths+1 tokens back through the page table.
    Returns (out [B,1,D], updated pool).  Rows whose write page is unmapped
    (inactive batch slots) are dropped by the scatter.
    """
    B = x.shape[0]
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    per_tok = 2 * KV * hd
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    pos = lengths[:, None]
    q, k, v = _project_qkv(p, cfg, x)
    if cfg.rope_theta > 0:
        sin, cos = layers.rope_sin_cos(pos, cfg.head_dim, cfg.rope_theta)
        q = layers.apply_rope(q, sin, cos)
        k = layers.apply_rope(k, sin, cos)
    kv_tok = jnp.stack([k[:, 0], v[:, 0]], axis=1).reshape(B, per_tok)
    chunk = lengths // tokens_per_page
    page = jnp.take_along_axis(page_table, chunk[:, None], axis=1)[:, 0]
    # drop writes past the table horizon (mirrors the dense cache's clamp)
    page = jnp.where(chunk < page_table.shape[1], page, -1)
    pool = kops.paged_kv_write(pool, kv_tok, page, lengths % tokens_per_page)
    n_pages = pool.shape[0]
    typed = pool[:, : tokens_per_page * per_tok].reshape(
        n_pages, tokens_per_page, 2, KV, hd)
    out = kops.paged_decode_attention(q, typed, page_table, lengths + 1,
                                      scale=cfg.head_dim ** -0.5, impl=impl)
    out = out.reshape(B, 1, cfg.n_heads * cfg.head_dim).astype(x.dtype)
    return hooks.attn_out(out @ p["wo"]), pool


def mla_paged_decode(p: Dict, cfg: ModelConfig, x: jax.Array,
                     pool: jax.Array, page_table: jax.Array, lengths,
                     *, tokens_per_page: int, hooks: Hooks = IDENTITY_HOOKS,
                     impl: Optional[str] = None,
                     ) -> Tuple[jax.Array, jax.Array]:
    """One-token absorbed-MLA decode against the shared paged KV pool.

    The per-token page row is [latent (r) | rope key (rp)] — the same
    untyped pool the GQA models page into, reinterpreted (Type II sharing).
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    per_tok = m.kv_lora_rank + m.qk_rope_head_dim
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    pos = lengths[:, None]
    q_nope, q_rope = _mla_queries(p, cfg, x, pos)
    latent_new, rope_new = _mla_latent(p, cfg, x, pos)
    kv_tok = jnp.concatenate([latent_new[:, 0], rope_new[:, 0]], axis=-1)
    chunk = lengths // tokens_per_page
    page = jnp.take_along_axis(page_table, chunk[:, None], axis=1)[:, 0]
    # drop writes past the table horizon (mirrors the dense cache's clamp)
    page = jnp.where(chunk < page_table.shape[1], page, -1)
    pool = kops.paged_kv_write(pool, kv_tok, page, lengths % tokens_per_page)
    n_pages = pool.shape[0]
    typed = pool[:, : tokens_per_page * per_tok].reshape(
        n_pages, tokens_per_page, per_tok)
    # absorb W_uk into q; score against [latent | rope] rows directly
    wuk = p["wuk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wuk)
    q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)      # [B,1,H,r+rp]
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    ctx = kops.paged_mla_decode_attention(
        q_cat, typed, page_table, lengths + 1,
        latent_dim=m.kv_lora_rank, scale=scale, impl=impl)
    wuv = p["wuv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhv->bshv", ctx, wuv)
    out = out.reshape(B, 1, H * m.v_head_dim).astype(x.dtype)
    return hooks.attn_out(out @ p["wo"]), pool


# ---------------------------------------------------------------------------
# Sliding-window decode (ring-buffer cache; gemma3 local layers)
# ---------------------------------------------------------------------------

def swa_decode(p: Dict, cfg: ModelConfig, x: jax.Array,
               cache_k: jax.Array, cache_v: jax.Array, cache_pos: jax.Array,
               cur_len, *, hooks: Hooks = IDENTITY_HOOKS,
               ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Decode with a ring-buffer window cache.

    cache: [B,W,KV,hd]; cache_pos: [B,W] absolute positions (-1 = empty);
    ``cur_len`` scalar (uniform) or [B].
    """
    B = x.shape[0]
    W = cache_k.shape[1]
    q, k, v = _project_qkv(p, cfg, x)
    cur = (jnp.broadcast_to(jnp.asarray(cur_len), (B,))
           if jnp.ndim(cur_len) > 0 else jnp.full((B,), cur_len, jnp.int32))
    pos = cur[:, None]
    if cfg.rope_theta > 0:
        sin, cos = layers.rope_sin_cos(pos, cfg.head_dim, cfg.rope_theta)
        q = layers.apply_rope(q, sin, cos)
        k = layers.apply_rope(k, sin, cos)
    slot = (cur % W)                                            # [B]
    hit = jnp.arange(W)[None, :] == slot[:, None]               # [B,W]
    cache_k = jnp.where(hit[:, :, None, None], k.astype(cache_k.dtype), cache_k)
    cache_v = jnp.where(hit[:, :, None, None], v.astype(cache_v.dtype), cache_v)
    cache_pos = jnp.where(hit, pos, cache_pos)
    cache_k, cache_v = hooks.kv(cache_k), hooks.kv(cache_v)
    valid = (cache_pos >= 0) & (cache_pos > (cur[:, None] - W))  # [B,W]
    mask = valid[:, None, None, None, :]
    out = attention_core(q, cache_k, cache_v, mask, cfg.head_dim ** -0.5)
    out = out.reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return hooks.attn_out(out @ p["wo"]), cache_k, cache_v, cache_pos


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype) -> Dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    p = {}
    if m.q_lora_rank:
        p["wdq"] = layers.dense_init(ks[0], (d, m.q_lora_rank), dtype)
        p["q_ln"] = jnp.zeros((m.q_lora_rank,), dtype)
        p["wuq"] = layers.dense_init(ks[1], (m.q_lora_rank, H * qk_dim), dtype)
    else:
        p["wuq"] = layers.dense_init(ks[1], (d, H * qk_dim), dtype)
    p["wdkv"] = layers.dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype)
    p["kv_ln"] = jnp.zeros((m.kv_lora_rank,), dtype)
    p["wuk"] = layers.dense_init(ks[3], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype)
    p["wuv"] = layers.dense_init(ks[4], (m.kv_lora_rank, H * m.v_head_dim), dtype)
    p["wo"] = layers.dense_init(ks[5], (H * m.v_head_dim, d), dtype)
    return p


def _mla_queries(p: Dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Returns (q_nope [B,S,H,nope], q_rope [B,S,H,rope]) with RoPE applied."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank:
        cq = layers.rms_norm(x @ p["wdq"], p["q_ln"], cfg.norm_eps)
        q = (cq @ p["wuq"]).reshape(B, S, H, qk_dim)
    else:
        q = (x @ p["wuq"]).reshape(B, S, H, qk_dim)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = q[..., m.qk_nope_head_dim:]
    sin, cos = layers.rope_sin_cos(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = layers.apply_rope(q_rope, sin, cos)
    return q_nope, q_rope


def _mla_latent(p: Dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Compressed KV: (latent [B,S,r] post-norm, k_rope [B,S,rope] post-RoPE).

    These two tensors are *the entire KV cache* — the paper's Type II case.
    """
    m = cfg.mla
    ckv = x @ p["wdkv"]
    latent = layers.rms_norm(ckv[..., : m.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope = ckv[..., m.kv_lora_rank:]
    sin, cos = layers.rope_sin_cos(positions, m.qk_rope_head_dim, cfg.rope_theta)
    k_rope = layers.apply_rope(k_rope[:, :, None, :], sin, cos)[:, :, 0, :]
    return latent, k_rope


def mla_full(p: Dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
             *, hooks: Hooks = IDENTITY_HOOKS,
             ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Whole-sequence MLA in the expanded (prefill/train) form."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_queries(p, cfg, x, positions)
    latent, k_rope = _mla_latent(p, cfg, x, positions)
    latent, k_rope = hooks.kv(latent), hooks.kv(k_rope)
    k_nope = (latent @ p["wuk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (latent @ p["wuv"]).reshape(B, S, H, m.v_head_dim)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    mask = causal_mask(positions, positions)[:, None, None, :, :]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  (B, S, H, m.qk_rope_head_dim))],
                        axis=-1)
    out = attention_core(q, k, v, mask, scale)
    out = out.reshape(B, S, H * m.v_head_dim)
    return hooks.attn_out(out @ p["wo"]), (latent, k_rope)


def mla_suffix(p: Dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
               prefix_latent: jax.Array, prefix_rope: jax.Array,
               kv_extent: int, *, hooks: Hooks = IDENTITY_HOOKS,
               ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Suffix-only expanded-form MLA against a cached prompt prefix.

    x: [B,S_suf,D] suffix hidden; positions: [B,S_suf] absolute;
    prefix_latent: [B,fork,r] / prefix_rope: [B,fork,rope] — the pool's
    compressed rows (post-norm latent, post-RoPE key) for the cached
    prefix; ``kv_extent``: the producing pass's bucket.  Same exactness
    argument as :func:`gqa_suffix` — the ``latent @ wuk`` / ``@ wuv``
    expansions are per-row, so padded latent rows only produce masked
    scores.  Returns (out, (latent_suf, rope_suf) for pool writing).
    """
    m = cfg.mla
    B, S = x.shape[:2]
    H = cfg.n_heads
    q_nope, q_rope = _mla_queries(p, cfg, x, positions)
    latent, k_rope = _mla_latent(p, cfg, x, positions)
    latent, k_rope = hooks.kv(latent), hooks.kv(k_rope)
    latent_all = _pad_to_extent(
        jnp.concatenate([prefix_latent.astype(latent.dtype), latent], axis=1),
        kv_extent)
    rope_all = _pad_to_extent(
        jnp.concatenate([prefix_rope.astype(k_rope.dtype), k_rope], axis=1),
        kv_extent)
    k_nope = (latent_all @ p["wuk"]).reshape(B, kv_extent, H,
                                             m.qk_nope_head_dim)
    v = (latent_all @ p["wuv"]).reshape(B, kv_extent, H, m.v_head_dim)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    kv_pos = jnp.arange(kv_extent)[None, :]
    mask = causal_mask(positions, kv_pos)[:, None, None, :, :]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(rope_all[:, :, None, :],
                                  (B, kv_extent, H, m.qk_rope_head_dim))],
        axis=-1)
    out = attention_core(q, k, v, mask, scale)
    out = out.reshape(B, S, H * m.v_head_dim)
    return hooks.attn_out(out @ p["wo"]), (latent, k_rope)


def mla_decode(p: Dict, cfg: ModelConfig, x: jax.Array,
               cache_latent: jax.Array, cache_rope: jax.Array, lengths,
               *, hooks: Hooks = IDENTITY_HOOKS,
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token MLA decode in the *absorbed* form.

    cache_latent: [B,T,r]; cache_rope: [B,T,rope].  Attention reads only the
    compressed latent — per-token KV bytes = (r + rope) * 2, independent of
    the 40 query heads.
    """
    m = cfg.mla
    B = x.shape[0]
    T = cache_latent.shape[1]
    H = cfg.n_heads
    pos = (jnp.broadcast_to(jnp.asarray(lengths), (B,))[:, None]
           if jnp.ndim(lengths) > 0 else jnp.full((B, 1), lengths, jnp.int32))
    q_nope, q_rope = _mla_queries(p, cfg, x, pos)
    latent_new, rope_new = _mla_latent(p, cfg, x, pos)
    # write to cache
    if jnp.ndim(lengths) == 0:
        idx = jnp.int32(lengths)
        cache_latent = jax.lax.dynamic_update_slice(
            cache_latent, latent_new.astype(cache_latent.dtype), (0, idx, 0))
        cache_rope = jax.lax.dynamic_update_slice(
            cache_rope, rope_new.astype(cache_rope.dtype), (0, idx, 0))
    else:
        slot = (jnp.arange(T)[None, :] == lengths[:, None])[:, :, None]
        cache_latent = jnp.where(slot, latent_new.astype(cache_latent.dtype), cache_latent)
        cache_rope = jnp.where(slot, rope_new.astype(cache_rope.dtype), cache_rope)
    cache_latent = hooks.kv(cache_latent)
    cache_rope = hooks.kv(cache_rope)
    # absorb W_uk into q:  q_lat [B,1,H,r]
    wuk = p["wuk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, wuk)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    if hooks.decode_attn_mla is not None:
        lengths_incl = jnp.broadcast_to(jnp.asarray(lengths) + 1, (B,))
        ctx_lat = hooks.decode_attn_mla(q_lat, q_rope, cache_latent,
                                        cache_rope, lengths_incl)
    else:
        if cache_latent.dtype.itemsize == 1:   # fp8 latent cache
            cache_latent = cache_latent.astype(jnp.bfloat16)
            cache_rope = cache_rope.astype(jnp.bfloat16)
        scores = (jnp.einsum("bshr,btr->bhst", q_lat, cache_latent,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshp,btp->bhst", q_rope, cache_rope,
                               preferred_element_type=jnp.float32))
        scores = scores * scale
        kv_pos = jnp.arange(T)[None, None, None, :]
        mask = kv_pos <= pos[:, None, :, None]   # [B,1,1,T] vs scores [B,H,1,T]
        scores = jnp.where(mask, scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(cache_latent.dtype)
        ctx_lat = jnp.einsum("bhst,btr->bshr", w, cache_latent)
    wuv = p["wuv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhv->bshv", ctx_lat, wuv)
    out = out.reshape(B, 1, H * m.v_head_dim)
    return hooks.attn_out(out @ p["wo"]), cache_latent, cache_rope
