"""Serving substrate: requests, traces, sampling, engine, simulator."""
