"""Serving launcher: the CrossPool engine over colocated cold models.

  python -m repro.launch.serve --rps 0.5 --horizon 20 --pipeline --lowering
  python -m repro.launch.serve --arch qwen3-14b --shape decode_32k --dry-run

Host-scale runs colocate the paper's model trio at smoke scale and report
decode TBT percentiles + pool statistics; --dry-run lowers the production
serve_step for an (arch x shape) cell instead.  ``--metrics-out`` /
``--trace-out`` attach an :class:`~repro.runtime.observe.EngineObserver`
and write Prometheus metrics / a Perfetto-loadable Chrome trace
(DESIGN.md §10) — CI's observability smoke step runs exactly that.
"""
from __future__ import annotations

import argparse
from typing import Optional

import numpy as np


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="dry-run arch")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--strategy", default="crosspool",
                    choices=["crosspool", "monolithic"])
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    # engine options
    ap.add_argument("--rps", type=float, default=0.5)
    ap.add_argument("--horizon", type=float, default=10.0)
    ap.add_argument("--pipeline", action="store_true", default=True)
    ap.add_argument("--no-pipeline", dest="pipeline", action="store_false")
    ap.add_argument("--lowering", action="store_true", default=True)
    ap.add_argument("--no-lowering", dest="lowering", action="store_false")
    ap.add_argument("--page-budget", type=int, default=8192)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="K tokens committed per fused decode dispatch "
                         "(DESIGN.md §9; host-driven lowering clamps to 1)")
    ap.add_argument("--cache", action="store_true",
                    help="enable radix-tree prefix caching over the KV "
                         "pool (DESIGN.md §11): trace requests get real "
                         "prompt ids sharing a per-model system prefix, "
                         "and the cache snapshot is reported")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write Prometheus-text metrics here after serving "
                         "(DESIGN.md §10)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write Chrome trace-event JSON here after serving "
                         "(open in Perfetto / chrome://tracing)")
    args = ap.parse_args(argv)

    if args.dry_run:
        from repro.launch import dryrun
        assert args.arch, "--arch required with --dry-run"
        rec = dryrun.run_cell(args.arch, args.shape,
                              multi_pod=args.multi_pod,
                              strategy_name=args.strategy)
        raise SystemExit(0 if rec.get("ok") else 1)

    from repro.configs import PAPER_COLOC_SET, get_smoke_config
    from repro.configs.base import CacheConfig, EngineConfig
    from repro.runtime import trace as trace_mod
    from repro.runtime.engine import CrossPoolEngine, EngineMode
    from repro.runtime.observe import EngineObserver, percentile

    observer = (EngineObserver()
                if args.metrics_out or args.trace_out else None)
    models = {n: get_smoke_config(n) for n in PAPER_COLOC_SET}
    engine = CrossPoolEngine(
        models, page_budget=args.page_budget, max_batch=4, max_ctx=128,
        config=EngineConfig(
            mode=EngineMode(pipeline=args.pipeline, lowering=args.lowering,
                            decode_steps_per_dispatch=args.decode_steps),
            cache=CacheConfig(enabled=args.cache)),
        observer=observer)
    reqs = trace_mod.make_requests(
        list(models), rps_per_model=args.rps, horizon_s=args.horizon,
        kind="sharegpt", scale_tokens=0.1, max_new_cap=args.max_new)
    if args.cache:
        # synthetic trace counts are cache-ineligible by design; give each
        # request REAL ids whose head is a per-model "system prompt" so
        # same-bucket requests share a cacheable prefix
        rng = np.random.default_rng(0)
        system = {n: rng.integers(0, models[n].vocab_size, 64)
                  .astype(np.int32) for n in models}
        for r in reqs:
            n = r.prompt_tokens
            ids = np.concatenate([system[r.model][:n], rng.integers(
                0, models[r.model].vocab_size, max(0, n - 64))])
            r.prompt_ids = ids[:n].astype(np.int32)
    print(f"serving {len(reqs)} requests across {len(models)} cold models "
          f"(pipeline={args.pipeline}, lowering={args.lowering}, "
          f"decode_steps={args.decode_steps})")
    stats = engine.run(reqs)
    print(f"tokens out: {stats.tokens_out}  virtual wall: {stats.wall_s:.2f}s "
          f"throughput: {stats.throughput:.1f} tok/s")
    print(f"TBT p50/p95/p99: {percentile(stats.tbt, 50) * 1e3:.1f} / "
          f"{percentile(stats.tbt, 95) * 1e3:.1f} / "
          f"{percentile(stats.tbt, 99) * 1e3:.1f} ms")
    print(f"admission: {engine.admission.stats}")
    print(f"pool: {engine.virt.utilization()}")
    if engine.cache is not None:
        print(f"prefix cache: {engine.cache.snapshot()}")
    print(f"straggler steps flagged: {stats.slow_steps}")
    if observer is not None:
        if args.metrics_out:
            observer.metrics.write(args.metrics_out)
            print(f"metrics -> {args.metrics_out}")
        if args.trace_out:
            observer.tracer.write(args.trace_out)
            print(f"trace -> {args.trace_out} "
                  f"({len(observer.tracer.events)} events)")


if __name__ == "__main__":
    main()
