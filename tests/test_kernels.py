"""Kernel allclose sweeps: every Pallas kernel vs. its pure-jnp oracle.

Kernels run in interpret mode (CPU executes the kernel body), oracles are
``repro.kernels.ref``.  Sweeps cover shapes (aligned + ragged), dtypes, and
GQA group structure; hypothesis drives property tests on the invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gemm import moe_gemm
from repro.kernels.paged_attention import (contiguous_decode_attention,
                                           paged_decode_attention,
                                           paged_mla_decode_attention)
from repro.kernels.ssd_chunked import ssd_scan_chunked
from repro.kernels.ssd_scan import ssd_scan


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,T,H,KV,D,bq,bk", [
    (1, 128, 128, 4, 4, 32, 64, 64),     # MHA, aligned
    (2, 64, 64, 8, 2, 16, 32, 32),       # GQA 4:1
    (1, 96, 96, 4, 1, 32, 64, 32),       # MQA, ragged q blocks
    (1, 32, 160, 4, 2, 16, 32, 64),      # prefix kv longer than q
    (2, 8, 8, 2, 2, 128, 8, 8),          # tiny blocks
])
def test_flash_attention_matches_ref(B, S, T, H, KV, D, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, S, H, D), dtype)
    k = _rand(ks[1], (B, T, KV, D), dtype)
    v = _rand(ks[2], (B, T, KV, D), dtype)
    scale = D ** -0.5
    out = flash_attention(q, k, v, scale=scale, block_q=bq, block_k=bk)
    want = ref.flash_attention(q, k, v, scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@settings(max_examples=20, deadline=None)
@given(s=st.sampled_from([16, 48, 64]),
       h=st.sampled_from([2, 4]),
       g=st.sampled_from([1, 2]),
       d=st.sampled_from([8, 32]))
def test_flash_attention_property(s, h, g, d):
    """Row-stochastic invariance: attention over constant v returns v."""
    kv = h // g if h % g == 0 else 1
    ks = jax.random.split(jax.random.PRNGKey(s * h + d), 2)
    q = _rand(ks[0], (1, s, h, d))
    k = _rand(ks[1], (1, s, kv, d))
    v = jnp.ones((1, s, kv, d), jnp.float32) * 3.5
    out = flash_attention(q, k, v, scale=d ** -0.5, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), 3.5, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention (contiguous + paged)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,KV,D,bt", [
    (2, 128, 4, 4, 32, 64),
    (3, 256, 8, 2, 16, 64),
    (1, 96, 4, 1, 32, 32),               # MQA, ragged
    (2, 64, 16, 2, 64, 64),
])
def test_contiguous_decode_matches_ref(B, T, H, KV, D, bt, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = _rand(ks[0], (B, 1, H, D), dtype)
    ck = _rand(ks[1], (B, T, KV, D), dtype)
    cv = _rand(ks[2], (B, T, KV, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, T + 1)
    out = contiguous_decode_attention(q, ck, cv, lengths, scale=D ** -0.5,
                                      block_t=bt)
    want = ref.decode_attention(q, ck, cv, lengths, D ** -0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,H,KV,D,ps,npages", [
    (2, 4, 2, 32, 16, 8),
    (1, 8, 1, 16, 8, 12),
    (3, 4, 4, 64, 32, 4),
])
def test_paged_decode_matches_ref(B, H, KV, D, ps, npages):
    """Paged kernel vs paged oracle, with a shuffled page table."""
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    n_phys = B * npages + 3
    q = _rand(ks[0], (B, 1, H, D))
    pages = _rand(ks[1], (n_phys, ps, 2, KV, D))
    # each request gets a random non-overlapping set of physical pages
    perm = jax.random.permutation(ks[2], n_phys)[: B * npages]
    table = perm.reshape(B, npages).astype(jnp.int32)
    lengths = jax.random.randint(ks[3], (B,), 1, npages * ps + 1)
    # unmap pages beyond length (virtualizer invariant)
    needed = (lengths[:, None] > jnp.arange(npages)[None, :] * ps)
    table = jnp.where(needed, table, -1)
    out = paged_decode_attention(q, pages, table, lengths, scale=D ** -0.5)
    want = ref.paged_decode_attention(q, pages, table, lengths, D ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,H,r,rp,ps,npages", [
    (2, 4, 16, 8, 8, 4),
    (1, 8, 32, 16, 16, 6),
    (3, 2, 8, 8, 4, 5),
])
def test_paged_mla_decode_matches_ref(B, H, r, rp, ps, npages):
    """Absorbed-MLA paged kernel vs its oracle, shuffled page table."""
    e = r + rp
    ks = jax.random.split(jax.random.PRNGKey(B * H + r), 4)
    n_phys = B * npages + 3
    q = _rand(ks[0], (B, 1, H, e))
    pages = _rand(ks[1], (n_phys, ps, e))
    perm = jax.random.permutation(ks[2], n_phys)[: B * npages]
    table = perm.reshape(B, npages).astype(jnp.int32)
    lengths = jax.random.randint(ks[3], (B,), 1, npages * ps + 1)
    needed = (lengths[:, None] > jnp.arange(npages)[None, :] * ps)
    table = jnp.where(needed, table, -1)
    out = paged_mla_decode_attention(q, pages, table, lengths,
                                     latent_dim=r, scale=e ** -0.5)
    want = ref.paged_mla_decode_attention(q, pages, table, lengths,
                                          r, e ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_equals_contiguous():
    """Paged attention over an identity page table == contiguous attention."""
    B, T, H, KV, D, ps = 2, 64, 4, 2, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = _rand(ks[0], (B, 1, H, D))
    ck = _rand(ks[1], (B, T, KV, D))
    cv = _rand(ks[2], (B, T, KV, D))
    lengths = jnp.array([40, 64], jnp.int32)
    npages = T // ps
    pages = jnp.stack(
        [ck.reshape(B, npages, ps, KV, D), cv.reshape(B, npages, ps, KV, D)],
        axis=3).reshape(B * npages, ps, 2, KV, D)
    table = jnp.arange(B * npages, dtype=jnp.int32).reshape(B, npages)
    out_p = paged_decode_attention(q, pages, table, lengths, scale=D ** -0.5)
    out_c = contiguous_decode_attention(q, ck, cv, lengths, scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_c),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# grouped expert GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,K,M,E,bn,bm", [
    (256, 64, 128, 4, 64, 64),
    (128, 32, 64, 8, 32, 32),
    (96, 16, 48, 3, 32, 16),             # ragged everything
    (64, 128, 256, 2, 64, 128),
])
def test_moe_gemm_matches_ref(N, K, M, E, bn, bm, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    x = _rand(ks[0], (N, K), dtype)
    w = _rand(ks[1], (E, K, M), dtype)
    # random ragged group sizes summing to N (some may be zero)
    cuts = np.sort(np.random.default_rng(N + E).integers(0, N + 1, E - 1))
    sizes = np.diff(np.concatenate([[0], cuts, [N]])).astype(np.int32)
    group_sizes = jnp.asarray(sizes)
    out = moe_gemm(x, w, group_sizes, block_n=bn, block_m=bm)
    want = ref.moe_gemm(x, w, group_sizes)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               **(_tol(dtype) if dtype == jnp.bfloat16
                                  else dict(rtol=1e-4, atol=1e-4)))


@settings(max_examples=15, deadline=None)
@given(e=st.integers(1, 6), n=st.sampled_from([32, 64]),
       seed=st.integers(0, 100))
def test_moe_gemm_property_block_identity(e, n, seed):
    """With w[e] = I for all e, grouped GEMM is the identity regardless of
    the grouping."""
    K = 16
    x = _rand(jax.random.PRNGKey(seed), (n, K))
    w = jnp.broadcast_to(jnp.eye(K)[None], (e, K, K))
    rng = np.random.default_rng(seed)
    cuts = np.sort(rng.integers(0, n + 1, e - 1))
    sizes = np.diff(np.concatenate([[0], cuts, [n]])).astype(np.int32)
    out = moe_gemm(x, w, jnp.asarray(sizes), block_n=16, block_m=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Mamba2 SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 64, 4, 16, 1, 16, 16),
    (2, 128, 8, 8, 2, 32, 32),
    (1, 32, 2, 64, 1, 8, 8),
    (2, 96, 6, 16, 3, 16, 32),           # H % block_h clamps
])
def test_ssd_scan_kernel_matches_sequential_ref(B, S, H, P, G, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = _rand(ks[0], (B, S, H, P), scale=0.5)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, H), scale=0.5))
    A = -jnp.exp(_rand(ks[2], (H,), scale=0.3))
    B_ = _rand(ks[3], (B, S, G, N), scale=0.5)
    C_ = _rand(ks[4], (B, S, G, N), scale=0.5)
    y, h = ssd_scan(x, dt, A, B_, C_, chunk=chunk)
    y_ref, h_ref = ref.ssd_scan(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-3, atol=1e-3)


def test_ssd_chunked_jnp_matches_sequential_ref():
    """The scalable chunked formulation (used by models) vs the recurrence."""
    B, S, H, P, G, N = 2, 128, 4, 16, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    x = _rand(ks[0], (B, S, H, P), scale=0.5)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, H), scale=0.5))
    A = -jnp.exp(_rand(ks[2], (H,), scale=0.3))
    B_ = _rand(ks[3], (B, S, G, N), scale=0.5)
    C_ = _rand(ks[4], (B, S, G, N), scale=0.5)
    y1, h1 = ssd_scan_chunked(x, dt, A, B_, C_, chunk=32)
    y2, h2 = ref.ssd_scan(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)


def test_ssd_scan_with_initial_state():
    """Chaining two half-sequences through h0 == one full scan (prefill
    semantics for the SSM-state 'cache')."""
    B, S, H, P, G, N = 1, 64, 2, 8, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = _rand(ks[0], (B, S, H, P), scale=0.5)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, H), scale=0.5))
    A = -jnp.exp(_rand(ks[2], (H,), scale=0.3))
    B_ = _rand(ks[3], (B, S, G, N), scale=0.5)
    C_ = _rand(ks[4], (B, S, G, N), scale=0.5)
    y_full, h_full = ssd_scan(x, dt, A, B_, C_, chunk=16)
    half = S // 2
    y1, h1 = ssd_scan(x[:, :half], dt[:, :half], A, B_[:, :half], C_[:, :half],
                      chunk=16)
    y2, h2 = ssd_scan(x[:, half:], dt[:, half:], A, B_[:, half:], C_[:, half:],
                      chunk=16, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)
