"""gemma3-12b — dense Gemma-3 [hf:google/gemma-3-1b-pt (family); unverified].

Assigned config: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144,
5:1 local:global sliding-window pattern, 128k context.  head_dim=256 per
gemma3-12b.  Local window = 1024 tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15_360,
    vocab_size=262_144,
    attention="gqa",
    qk_norm=True,
    sliding_window=1024,
    swa_pattern=6,           # every 6th layer global => 5:1 local:global
    rope_theta=1_000_000.0,
    max_position=131_072,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt family; unverified",
)

SMOKE = CONFIG.replace(
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=256, sliding_window=16, max_position=512,
)
