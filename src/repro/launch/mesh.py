"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state; callers control when
devices are enumerated.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to build these meshes on CPU.

Target hardware model: TPU v5e pods — 256 chips/pod in a (16,16) ICI torus.
Single-pod mesh: (data=16, model=16).  Multi-pod: (pod=2, data=16, model=16)
where the ``pod`` axis crosses DCN and is used only for pure-DP (training)
or replica scale-out (serving).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import Mesh

# TPU v5e roofline constants (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link (~4 links usable/chip)
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: Optional[int] = None, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / CPU engine runs)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_chip_count(mesh: Mesh) -> int:
    return math.prod(mesh.shape.values())
