"""Mixture-of-Experts FFN: top-k router + capacity-based expert dispatch.

This is the module the CrossPool *weights pool* consolidates: expert weights
are stored once (stacked ``[E, ...]``) and shardable over any mesh axis via
``hooks.moe_inputs`` / ``hooks.moe_hidden``.  The dispatch is the standard
capacity-factor formulation (GShard/Switch): each expert processes at most
``C = ceil(N * k * capacity_factor / E)`` tokens; overflow tokens fall back
to the residual path (dropped from the FFN), which matches the router
semantics serving engines use at low batch.

Two FLOPs-relevant properties (they matter for the §Roofline tables):
  * compiled FLOPs scale with E*C ≈ N*k*cf — i.e. *active* expert compute,
    not all-expert compute;
  * the gather/scatter dispatch is data movement, not matmul FLOPs.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.hooks import Hooks, IDENTITY_HOOKS
from repro.kernels import ops as kops

#: Leaves of ``init_moe`` stacked over the leading expert axis ``[E, ...]``.
#: The weights-pool virtualizer slices these per expert into arena slab
#: units (``repro.core.weight_pool``); everything else in the tree (router,
#: shared experts) is per-layer.  Keep in sync with :func:`init_moe`.
EXPERT_STACKED_LEAVES = ("wg", "wu", "wd")


def init_moe(key, cfg: ModelConfig, dtype) -> Dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(ks[0], (d, E), jnp.float32),
        "wg": layers.dense_init(ks[1], (E, d, f), dtype, in_axis=1),
        "wu": layers.dense_init(ks[2], (E, d, f), dtype, in_axis=1),
        "wd": layers.dense_init(ks[3], (E, f, d), dtype, in_axis=1),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.init_mlp(ks[4], d, cfg.n_shared_experts * f,
                                      "swiglu", dtype)
    return p


def expert_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    """Per-expert token capacity C (static — shapes must not depend on data)."""
    c = math.ceil(n_tokens * cfg.experts_per_token * cfg.capacity_factor
                  / cfg.n_experts)
    # MXU alignment: round C up to a multiple of 8 (sublane) when large enough.
    return max(8, ((c + 7) // 8) * 8) if c > 8 else max(c, 1)


def route(p: Dict, x: jax.Array, cfg: ModelConfig
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. x: [N,D] -> (gates [N,k], experts [N,k], router_probs [N,E])."""
    logits = (x.astype(jnp.float32) @ p["router"])          # [N,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)  # renormalize top-k
    return gates, experts, probs


def dispatch_indices(experts: jax.Array, n_experts: int, capacity: int,
                     offset: Optional[jax.Array] = None,
                     ) -> Tuple[jax.Array, jax.Array]:
    """Compute each (token, k) pair's slot within its expert.

    experts: [N,k] int32.  Returns (slot [N,k] int32 position-in-expert,
    keep [N,k] bool — False when over capacity).
    Pure cumsum formulation: position of pair (n,j) within expert e equals
    the number of *earlier* pairs routed to e (row-major (n,j) order).

    ``offset`` ([E] int32) pre-counts pairs routed to each expert by tokens
    that come BEFORE this call's tokens in the same logical sequence —
    the suffix-prefill path passes the cached prefix's routed-pair counts
    so the suffix's slots (and hence capacity drops) land exactly where a
    full-prompt pass would have put them.
    """
    N, k = experts.shape
    flat = experts.reshape(-1)                               # [N*k]
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)  # [N*k, E]
    pos = jnp.cumsum(onehot, axis=0) - onehot                # exclusive cumsum
    slot = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    if offset is not None:
        slot = slot + jnp.take(offset.astype(slot.dtype), flat)
    keep = slot < capacity
    return slot.reshape(N, k), keep.reshape(N, k)


def apply_moe(p: Dict, x: jax.Array, cfg: ModelConfig, *,
              hooks: Hooks = IDENTITY_HOOKS,
              capacity: Optional[int] = None,
              slot_offset: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, jax.Array]:
    """Routed expert FFN.

    x: [B,S,D] (or [N,D]).  Returns (out same shape, aux_loss scalar —
    the Switch load-balance loss, used by the training substrate).

    ``slot_offset`` ([E]) shifts each expert's dispatch slots as if that
    many pairs were already routed there (see :func:`dispatch_indices`);
    pair it with the producing pass's ``capacity`` for prefix-cached
    suffix prefill.
    """
    orig_shape = x.shape
    d = cfg.d_model
    xf = x.reshape(-1, d)                                    # [N,D]
    N = xf.shape[0]
    E, k = cfg.n_experts, cfg.experts_per_token
    C = capacity or expert_capacity(N, cfg)

    gates, experts, probs = route(p, xf, cfg)                # [N,k]x2, [N,E]
    slot, keep = dispatch_indices(experts, E, C, offset=slot_offset)

    # ---- dispatch: scatter tokens into [E, C, D] ---------------------------
    flat_expert = experts.reshape(-1)                        # [N*k]
    flat_slot = slot.reshape(-1)
    flat_keep = keep.reshape(-1)
    flat_dst = jnp.where(flat_keep, flat_expert * C + flat_slot, E * C)
    token_ids = jnp.repeat(jnp.arange(N), k)                 # [N*k]
    x_src = xf[token_ids]                                    # [N*k, D]
    buf = jnp.zeros((E * C + 1, d), xf.dtype)
    buf = buf.at[flat_dst].set(x_src)                        # drop row E*C
    expert_in = buf[: E * C].reshape(E, C, d)
    expert_in = hooks.moe_inputs(expert_in)

    # ---- expert computation (stacked SwiGLU over the E axis) ---------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["wg"])) \
        * jnp.einsum("ecd,edf->ecf", expert_in, p["wu"])
    h = hooks.moe_hidden(h)
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wd"])      # [E,C,D]
    expert_out = hooks.moe_inputs(expert_out)

    # ---- combine: gather back and weight by gates --------------------------
    flat_out = expert_out.reshape(E * C, d)
    safe_dst = jnp.minimum(flat_dst, E * C - 1)
    y_pairs = flat_out[safe_dst] * (gates.reshape(-1) * flat_keep)[:, None]
    y = jax.ops.segment_sum(y_pairs.astype(jnp.float32), token_ids,
                            num_segments=N).astype(x.dtype)

    # ---- shared experts (always-on residual experts; DeepSeek-style) -------
    if cfg.n_shared_experts:
        y = y + layers.apply_mlp(p["shared"], xf, "swiglu",
                                 hook=hooks.ffn_hidden)

    # ---- load-balance aux loss (Switch): E * sum_e f_e * P_e ---------------
    pair_onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)  # [N,k,E]
    frac_tokens = jnp.mean(jnp.sum(pair_onehot, axis=1), axis=0)  # [E]
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) / k

    return y.reshape(orig_shape), aux


def make_moe_a2a(mesh, cfg: ModelConfig, *, expert_axis: str = "data",
                 tp_axis: str = "model", batch_axes=None,
                 capacity_mult: float = 1.25, f8_dispatch: bool = False):
    """Explicit all-to-all expert dispatch via shard_map (beyond-paper opt).

    The XLA-SPMD formulation of ``apply_moe`` lets the partitioner choose
    the dispatch collectives; on cold-decode batches it emits full-buffer
    all-gathers + all-reduces (~16 MB/layer/device).  This version pins the
    MegaScale-Infer-style schedule explicitly:

      tokens sharded over ``expert_axis`` | experts sharded over the same
      axis | per-(src,dst) send buffers | ONE all_to_all out (payload =
      each token travels once) | local capacity-dispatch to the shard's own
      experts (f sharded over ``tp_axis``) | psum over tp | ONE all_to_all
      back | weighted combine.

    Collective payload per layer: 2 * N * d * itemsize / shards + the tp
    psum — ~8x less than the SPMD-chosen schedule at decode batch sizes.

    Returns fn(params_moe, x [B,S,d]) -> (out, aux) with the same routing
    semantics as ``apply_moe`` (top-k, renormalized gates, capacity drop).
    """
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map as _sm

        def _shard_map(f, in_specs, out_specs):
            return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_vma=False)
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map as _sm

        def _shard_map(f, in_specs, out_specs):
            return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_rep=False)

    n_shards = mesh.shape[expert_axis]
    E, k, d = cfg.n_experts, cfg.experts_per_token, cfg.d_model
    assert E % n_shards == 0, (E, n_shards)
    E_loc = E // n_shards

    def local(p_router, wg, wu, wd, x):
        # x: [B_loc, S, d] tokens of this expert-axis shard (replicated
        # over tp); wg/wu/wd: [E_loc, d, f_loc]
        Bl, S, _ = x.shape
        xf = x.reshape(-1, d)
        Nl = xf.shape[0]
        logits = xf.astype(jnp.float32) @ p_router
        probs = jax.nn.softmax(logits, axis=-1)
        gates, experts = jax.lax.top_k(probs, k)              # [Nl,k]
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

        owner = experts // E_loc                              # dst shard
        local_eid = experts % E_loc
        # send-slot within (this shard -> dst) buffer
        C2 = max(8, int(math.ceil(Nl * k / n_shards * capacity_mult)))
        flat_owner = owner.reshape(-1)
        onehot = jax.nn.one_hot(flat_owner, n_shards, dtype=jnp.int32)
        slot = (jnp.cumsum(onehot, axis=0) - onehot)[
            jnp.arange(Nl * k), flat_owner]
        keep = slot < C2
        dst = jnp.where(keep, flat_owner * C2 + slot, n_shards * C2)
        tok_ids = jnp.repeat(jnp.arange(Nl), k)

        # fp8 dispatch transport (DeepSeek-V3 style: fp8 out, bf16 back):
        # halves the dominant a2a payload; expert inputs are dequantized
        # before the GEMMs.
        xmit_dt = jnp.float8_e4m3fn if f8_dispatch else x.dtype
        send_x = jnp.zeros((n_shards * C2 + 1, d), xmit_dt)
        send_x = send_x.at[dst].set(xf[tok_ids].astype(xmit_dt))[:-1]
        send_meta = jnp.full((n_shards * C2 + 1,), -1, jnp.int32)
        send_meta = send_meta.at[dst].set(local_eid.reshape(-1))[:-1]

        # one hop: each (token,k) pair travels once
        recv_x = jax.lax.all_to_all(
            send_x.reshape(n_shards, C2, d), expert_axis, 0, 0,
            tiled=False).astype(x.dtype)
        recv_meta = jax.lax.all_to_all(
            send_meta.reshape(n_shards, C2), expert_axis, 0, 0, tiled=False)
        recv_x = recv_x.reshape(n_shards * C2, d)
        recv_meta = recv_meta.reshape(n_shards * C2)

        # local capacity dispatch to this shard's E_loc experts
        valid = recv_meta >= 0
        eid = jnp.where(valid, recv_meta, 0)
        C3 = max(8, int(math.ceil(n_shards * C2 / max(E_loc, 1)
                                  * capacity_mult)))
        oh = jax.nn.one_hot(eid, E_loc, dtype=jnp.int32) \
            * valid[:, None].astype(jnp.int32)
        pos = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(n_shards * C2), eid]
        keep3 = valid & (pos < C3)
        dst3 = jnp.where(keep3, eid * C3 + pos, E_loc * C3)
        buf = jnp.zeros((E_loc * C3 + 1, d), x.dtype)
        buf = buf.at[dst3].set(recv_x)[:-1]
        ein = buf.reshape(E_loc, C3, d)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein, wg)) \
            * jnp.einsum("ecd,edf->ecf", ein, wu)
        out = jnp.einsum("ecf,efd->ecd", h, wd)               # partial (f/tp)

        # undo local dispatch, send back PARTIAL sums (the tp reduction
        # commutes with the linear combine, so it happens on the tiny
        # token-space output below instead of the padded expert space —
        # [Bl,S,d] vs [E_loc,C3,d] psum payload, ~30x less)
        flat = out.astype(x.dtype).reshape(E_loc * C3, d)
        back = jnp.where(keep3[:, None],
                         flat[jnp.minimum(dst3, E_loc * C3 - 1)], 0.0)
        ret = jax.lax.all_to_all(
            back.reshape(n_shards, C2, d), expert_axis, 0, 0, tiled=False)
        ret = ret.reshape(n_shards * C2, d)
        y_pairs = jnp.where(keep[:, None],
                            ret[jnp.minimum(dst, n_shards * C2 - 1)], 0.0)
        w_pairs = gates.reshape(-1) * keep
        y = jax.ops.segment_sum(
            (y_pairs * w_pairs[:, None]).astype(jnp.float32), tok_ids,
            num_segments=Nl)
        # psum in bf16: halves the payload; the f32 accumulation above
        # already absorbed the k-way gate-weighted sum
        y = jax.lax.psum(y.astype(x.dtype), tp_axis)

        pair_onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)
        # pmean the FACTORS (not the product): the load-balance loss uses
        # global token fractions x global router probs
        frac_tokens = jax.lax.pmean(
            jnp.mean(jnp.sum(pair_onehot, axis=1), axis=0), expert_axis)
        mean_probs = jax.lax.pmean(jnp.mean(probs, axis=0), expert_axis)
        aux = E * jnp.sum(frac_tokens * mean_probs) / k
        return y.reshape(Bl, S, d), aux

    B_spec = batch_axes if batch_axes else expert_axis

    def apply(p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        fn = _shard_map(
            local,
            in_specs=(P(None, None), P(expert_axis, None, tp_axis),
                      P(expert_axis, None, tp_axis),
                      P(expert_axis, tp_axis, None), P(B_spec, None, None)),
            out_specs=(P(B_spec, None, None), P()),
        )
        y, aux = fn(p["router"], p["wg"], p["wu"], p["wd"], x)
        if cfg.n_shared_experts:
            y = y + layers.apply_mlp(p["shared"], x.reshape(-1, d),
                                     "swiglu").reshape(x.shape)
        return y, aux

    return apply


def apply_moe_grouped(p: Dict, x: jax.Array, cfg: ModelConfig, *,
                      hooks: Hooks = IDENTITY_HOOKS) -> Tuple[jax.Array, jax.Array]:
    """Token-sorted grouped-GEMM MoE path (uses the ``moe_gemm`` kernel).

    Sorts (token,k) pairs by expert, runs a ragged grouped matmul (no
    capacity drop), and unsorts.  Used on the single-host engine path where
    exact no-drop semantics are preferred; the capacity path above is the
    SPMD/dry-run path.
    """
    orig_shape = x.shape
    d = cfg.d_model
    xf = x.reshape(-1, d)
    N = xf.shape[0]
    E, k = cfg.n_experts, cfg.experts_per_token

    gates, experts, probs = route(p, xf, cfg)
    flat_expert = experts.reshape(-1)                        # [N*k]
    order = jnp.argsort(flat_expert)
    token_ids = jnp.repeat(jnp.arange(N), k)[order]
    x_sorted = xf[token_ids]                                 # [N*k, D]
    group_sizes = jnp.bincount(flat_expert, length=E)

    h = jax.nn.silu(kops.moe_gemm(x_sorted, p["wg"], group_sizes)) \
        * kops.moe_gemm(x_sorted, p["wu"], group_sizes)
    out_sorted = kops.moe_gemm(h, p["wd"], group_sizes)      # [N*k, D]

    w_sorted = gates.reshape(-1)[order]
    y = jax.ops.segment_sum((out_sorted * w_sorted[:, None]).astype(jnp.float32),
                            token_ids, num_segments=N).astype(x.dtype)
    if cfg.n_shared_experts:
        y = y + layers.apply_mlp(p["shared"], xf, "swiglu",
                                 hook=hooks.ffn_hidden)
    pair_onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)
    frac_tokens = jnp.mean(jnp.sum(pair_onehot, axis=1), axis=0)
    aux = E * jnp.sum(frac_tokens * jnp.mean(probs, axis=0)) / k
    return y.reshape(orig_shape), aux
