"""Train a ~100M-param MoE (qwen3-moe family, scaled) for a few hundred
steps on CPU — the training-substrate end-to-end driver.

Demonstrates: routed-expert FFN with load-balance aux loss, microbatched
gradient accumulation, remat, async checkpointing + resume, and
error-feedback int8 gradient compression.

  PYTHONPATH=src python examples/train_moe.py --steps 200
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import AdamW
from repro.training.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--compress", action="store_true")
    args = ap.parse_args()

    # ~100M-param member of the qwen3-moe family
    cfg = get_config("qwen3-moe-235b-a22b").replace(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=256, n_experts=16, experts_per_token=2, vocab_size=2048,
        max_position=2048, dtype="float32")
    model = build_model(cfg)
    n_params = cfg.param_counts()["total"]
    print(f"training {n_params / 1e6:.1f}M-param MoE "
          f"({cfg.n_experts} experts, top-{cfg.experts_per_token})")

    optimizer = AdamW(lr=3e-3, warmup_steps=20)
    state = init_train_state(model, optimizer, jax.random.PRNGKey(0),
                             compress=args.compress)
    step = jax.jit(make_train_step(
        model, optimizer, num_microbatches=args.microbatches,
        compress=args.compress, remat=True))
    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch,
                                  noise=0.05))

    ckpt_dir = tempfile.mkdtemp(prefix="train_moe_ckpt_")
    losses = []
    t0 = time.time()
    for i, batch in zip(range(args.steps), data.batches()):
        state, metrics = step(state, {"tokens": jnp.asarray(batch["tokens"])})
        losses.append(float(metrics["ce"]))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} ce {losses[-1]:.4f} "
                  f"aux {float(metrics['aux']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
        if (i + 1) % 100 == 0:
            ckpt.save_async(state, i + 1, ckpt_dir)
    dt = time.time() - t0
    print(f"{args.steps} steps in {dt:.0f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"ce: {first:.3f} -> {last:.3f}")
    assert last < first * 0.8, "MoE failed to learn"
    print("train_moe OK")


if __name__ == "__main__":
    main()
