"""Long-context burst into the shared KV pool: the paper's Fig. 6 scenario
end-to-end at host scale.

A burst of LongAlign-like long-context requests arrives for ONE cold model
while two other models idle-hold their weights.  Under a static per-model
partition the burst would be rejected (per-model KV slice too small);
under the CrossPool shared pool the planner's budget absorbs it.  Also
demonstrates the paged virtualizer's device pool + the Pallas paged
decode-attention kernel reading through the page table.
"""
import jax.numpy as jnp
import numpy as np

from repro.configs import PAPER_COLOC_SET, get_smoke_config
from repro.core.admission import AdmissionController, PendingRequest
from repro.core.virtualizer import KVVirtualizer
from repro.kernels.paged_attention import paged_decode_attention
from repro.kernels import ref


def main():
    models = {n: get_smoke_config(n) for n in PAPER_COLOC_SET}
    total_pages = 512
    # static partition: each model owns a third of the pages
    static_share = total_pages // 3

    virt = KVVirtualizer(models, page_budget=total_pages, page_bytes=4096,
                         allocate_device_pool=False)
    ac = AdmissionController(virt, max_queue_per_model=2)

    # burst on the GQA MoE model (fattest kappa — MLA's compressed KV is
    # deliberately tiny, which is its own selling point)
    burst_model = "moonshot-v1-16b-a3b"
    view = virt.views[burst_model]
    long_ctx = 1024                         # "long" at smoke scale
    need = view.pages_for(long_ctx)
    print(f"burst: 4 x {long_ctx}-token requests for {burst_model} "
          f"({need} pages each; static share = {static_share} pages)")
    assert need > static_share // 2, "burst must stress the static share"

    outcomes = []
    for i in range(4):
        outcomes.append(ac.offer(
            PendingRequest(i, burst_model, long_ctx, 0, 0.0), 0.0))
    admitted_shared = outcomes.count("admitted")
    admitted_static = min(static_share // need, 4)
    print(f"shared pool admitted {admitted_shared}/4; a static partition "
          f"would admit {admitted_static}/4")
    assert admitted_shared > admitted_static

    # --- paged decode attention through the virtualizer (MLA model) ------
    mla_model = "minicpm3-4b"
    m = models[mla_model].mla
    virt2 = KVVirtualizer({mla_model: models[mla_model]},
                          page_budget=64, page_bytes=2048)
    virt2.register_request(0, mla_model, prompt_tokens=48)
    v2 = virt2.views[mla_model]
    rng = np.random.default_rng(0)
    kv = jnp.asarray(rng.normal(size=(48, *v2.kv_shape)), jnp.bfloat16)
    virt2.write_tokens(mla_model, 0, 0, 0, kv)
    table = virt2.page_table_array([0], 0, max_pages=8)
    # read the latent cache back through the page table and attend over it
    typed = virt2.typed_pages(mla_model)      # [pages, tpp, r+rope]
    r = m.kv_lora_rank
    pages_lat = typed[..., :r]
    H = models[mla_model].n_heads
    q = jnp.asarray(rng.normal(size=(1, 1, H, r)), jnp.float32)
    # pack pages as [p, tpp, 2, 1, r] (K=V=latent) for the generic kernel
    packed = jnp.stack([pages_lat, pages_lat], axis=2)[:, :, :, None, :]
    lengths = jnp.array([48], jnp.int32)
    out = paged_decode_attention(q.astype(jnp.float32),
                                 packed.astype(jnp.float32), table, lengths,
                                 scale=r ** -0.5)
    want = ref.paged_decode_attention(q.astype(jnp.float32),
                                      packed.astype(jnp.float32), table,
                                      lengths, r ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5,
                               atol=2e-5)
    print(f"paged attention over the virtualized pool: out {out.shape}, "
          f"matches oracle")
    print(f"pool util: {virt2.utilization()}")
    print("long_context_pooling OK")


if __name__ == "__main__":
    main()
